"""Request-lifecycle tracing: nested spans + instant events (DESIGN.md §15).

A :class:`Tracer` records begin/end span pairs and instant events into an
in-memory buffer, one event per ``list.append`` (GIL-atomic, so loader
threads and the scheduler thread can share a tracer without locks).  The
buffer exports to Chrome ``trace_event`` JSON — loadable in
``chrome://tracing`` / Perfetto — and to line-per-event JSONL.

Design points:

* **Negligible overhead when disabled.**  ``Tracer(enabled=False).span(...)``
  returns a module-level singleton null context manager and records nothing;
  the per-call cost is one attribute check.  Use the shared
  :data:`NULL_TRACER` when a component takes an optional tracer.
* **Injectable clock.**  The constructor takes ``clock=`` (defaults to
  ``time.perf_counter``) so tests can drive deterministic timelines.
* **Per-role process ids.**  Each tracer carries a ``role`` label; the
  Chrome export emits it as the process name, so a disaggregated run's
  materializer and decode traces merge into one timeline with two process
  lanes (:func:`merge_chrome`).  Spans carry ``req=``/``chunk=`` args that
  act as the cross-role join keys.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

TRACE_SCHEMA = 1

# event tuple layout: (ts_seconds, thread_ident, phase, name, args_or_None)
_Event = Tuple[float, int, str, str, Optional[Dict[str, Any]]]


class _NullSpan:
    """No-op context manager returned by a disabled tracer.

    A single module-level instance is shared by every disabled ``span()``
    call — the disabled fast path allocates nothing (asserted in tests).
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span handle; closing records the matching E event."""

    __slots__ = ("_tracer", "name")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._tracer = tracer
        self.name = name
        tracer._record("B", name, args)

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc: object) -> bool:
        self._tracer._record("E", self.name, None)
        return False


class Tracer:
    """Collects span/instant events for one role (process lane)."""

    def __init__(self, enabled: bool = True, *, role: str = "serve",
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.enabled = enabled
        self.role = role
        self.clock = clock
        self.events: List[_Event] = []

    # -- recording -----------------------------------------------------------

    def _record(self, ph: str, name: str,
                args: Optional[Dict[str, Any]]) -> None:
        self.events.append(
            (self.clock(), threading.get_ident(), ph, name, args or None))

    def span(self, name: str, **args: Any) -> Any:
        """Open a nested span; use as a context manager.

        ``with tracer.span("flash_read", chunk=cid): ...``
        """
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        if self.enabled:
            self._record("i", name, args)

    def clear(self) -> None:
        self.events = []

    # -- analysis ------------------------------------------------------------

    def spans(self) -> Iterator[Tuple[str, float, float, int,
                                      Optional[Dict[str, Any]]]]:
        """Yield completed spans as ``(name, t0, dur, tid, args)``.

        Replays the event buffer with a per-thread stack; raises
        ``ValueError`` on mismatched begin/end pairs (spans must strictly
        nest per thread — the invariant the tests pin).
        """
        stacks: Dict[int, List[Tuple[str, float,
                                     Optional[Dict[str, Any]]]]] = {}
        for ts, tid, ph, name, args in self.events:
            if ph == "B":
                stacks.setdefault(tid, []).append((name, ts, args))
            elif ph == "E":
                stack = stacks.get(tid)
                if not stack or stack[-1][0] != name:
                    raise ValueError(
                        f"unbalanced span end {name!r} on thread {tid}")
                bname, t0, bargs = stack.pop()
                yield bname, t0, ts - t0, tid, bargs
        for tid, stack in stacks.items():
            if stack:
                raise ValueError(
                    f"unclosed spans on thread {tid}: "
                    f"{[s[0] for s in stack]}")

    def totals(self) -> Dict[str, Tuple[int, float]]:
        """Inclusive ``{span_name: (count, total_seconds)}``."""
        out: Dict[str, Tuple[int, float]] = {}
        for name, _t0, dur, _tid, _args in self.spans():
            n, tot = out.get(name, (0, 0.0))
            out[name] = (n + 1, tot + dur)
        return out

    # -- export --------------------------------------------------------------

    def to_chrome_dict(self, pid: int = 1) -> Dict[str, Any]:
        """Chrome ``trace_event`` document (``{"traceEvents": [...]}``)."""
        tid_map: Dict[int, int] = {}
        evs: List[Dict[str, Any]] = [
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": self.role}},
        ]
        for ts, raw_tid, ph, name, args in self.events:
            tid = tid_map.setdefault(raw_tid, len(tid_map) + 1)
            ev: Dict[str, Any] = {"name": name, "ph": ph, "pid": pid,
                                  "tid": tid, "ts": ts * 1e6}
            if args:
                ev["args"] = dict(args)
            if ph == "i":
                ev["s"] = "t"
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"role": self.role, "schema": TRACE_SCHEMA}}

    def to_chrome(self, path: str) -> Dict[str, Any]:
        doc = self.to_chrome_dict()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps({"schema": TRACE_SCHEMA,
                                "role": self.role}) + "\n")
            for ts, tid, ph, name, args in self.events:
                rec: Dict[str, Any] = {"ts": ts, "tid": tid, "ph": ph,
                                       "name": name}
                if args:
                    rec["args"] = args
                f.write(json.dumps(rec) + "\n")


NULL_TRACER = Tracer(enabled=False, role="null")


def span_overlap_frac(tracer: Tracer, name: str, against: str) -> float:
    """Fraction of total ``name``-span time that overlaps the union of
    ``against``-span intervals.

    The load/decode-overlap gauge: with ``("flash_read", "decode_step")``
    it answers *how much of the flash-read wall time was hidden behind
    decode steps* — 0.0 means every read byte stalled the scheduler,
    1.0 means the link ran entirely in decode's shadow. Spans may come
    from different threads (loader workers vs the scheduler thread); only
    their wall-clock intervals matter. Returns 0.0 when either span set
    is empty.
    """
    target: List[Tuple[float, float]] = []
    other: List[Tuple[float, float]] = []
    for sname, t0, dur, _tid, _args in tracer.spans():
        if sname == name:
            target.append((t0, t0 + dur))
        elif sname == against:
            other.append((t0, t0 + dur))
    total = sum(e - s for s, e in target)
    if not total or not other:
        return 0.0
    other.sort()
    merged: List[List[float]] = [list(other[0])]
    for s, e in other[1:]:
        if s <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], e)
        else:
            merged.append([s, e])
    hidden = 0.0
    for s, e in target:
        for ms, me in merged:
            if me <= s:
                continue
            if ms >= e:
                break
            hidden += min(e, me) - max(s, ms)
    return hidden / total


# ---------------------------------------------------------------------------
# Chrome-document level helpers (merge + validate)
# ---------------------------------------------------------------------------

def merge_chrome(*docs: Dict[str, Any]) -> Dict[str, Any]:
    """Merge per-role Chrome trace documents into one timeline.

    Each input document gets a distinct pid (its process lane); events are
    otherwise untouched, so the shared wall clock lines the roles up and
    ``req=``/``chunk=`` span args join work across roles.
    """
    merged: List[Dict[str, Any]] = []
    roles = []
    for pid, doc in enumerate(docs, start=1):
        roles.append(str(doc.get("otherData", {}).get("role", f"role{pid}")))
        for ev in doc["traceEvents"]:
            ev = dict(ev)
            ev["pid"] = pid
            merged.append(ev)
    return {"traceEvents": merged, "displayTimeUnit": "ms",
            "otherData": {"roles": roles, "schema": TRACE_SCHEMA}}


def arg_values(doc: Dict[str, Any], key: str) -> set:
    """All values of span/instant arg ``key`` in a Chrome document — the
    join-key extractor used to check that per-role traces actually merge."""
    out = set()
    for ev in doc["traceEvents"]:
        args = ev.get("args")
        if isinstance(args, dict) and key in args:
            out.add(args[key])
    return out


def validate_chrome(doc: Dict[str, Any]) -> Dict[str, int]:
    """Validate a Chrome trace document's schema; raise ``ValueError``.

    Checks: ``traceEvents`` is a list of dicts with name/ph/pid/tid; B/E
    events pair up per (pid, tid) with non-decreasing timestamps; instant
    events carry numeric ``ts``.  Returns ``{"events": n, "spans": m}``.
    """
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        raise ValueError("traceEvents missing or not a list")
    stacks: Dict[Tuple[Any, Any], List[Tuple[str, float]]] = {}
    n_spans = 0
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} missing {k!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            raise ValueError(f"event {i} ({ev['name']!r}) has no numeric ts")
        key = (ev["pid"], ev["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append((ev["name"], ts))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} without matching B")
            bname, bts = stack.pop()
            if bname != ev["name"]:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} closes B {bname!r} "
                    f"(spans must nest)")
            if ts < bts:
                raise ValueError(
                    f"event {i}: span {bname!r} ends before it begins")
            n_spans += 1
        elif ph != "i":
            raise ValueError(f"event {i}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        if stack:
            raise ValueError(
                f"unclosed B events on {key}: {[s[0] for s in stack]}")
    return {"events": len(evs), "spans": n_spans}


def load_chrome(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
