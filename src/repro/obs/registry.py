"""Named metrics primitives: counters, gauges, histograms (DESIGN.md §15).

A :class:`MetricsRegistry` is the single sink the scheduler, pool, loader
and role workers write into, replacing the hand-threaded int plumbing that
previously fed ``ServeMetrics`` field by field.  ``ServeMetrics`` is now a
*view* over a registry (``ServeMetrics.from_registry``), so adding a new
measurement means adding one ``reg.counter(...).inc(...)`` call, not a new
dataclass field threaded through four layers.

Conventions (enforced only by usage, kept flat on purpose):

* ``serve.*``    — whole-run counts (requests, tokens, bytes, hits/misses)
* ``phase.*_s``  — wall-clock seconds per lifecycle phase (float counters)
* ``request.*``  — per-request histograms (latency, TTFT, queue wait, bytes)
* ``decode.*``   — per-step measurement (steps, row-steps, measured KV bytes)
* ``pool.*`` / ``mat.*`` — pool residency gauges / materializer-role counts
"""

from __future__ import annotations

from typing import Dict, List, Union

Number = Union[int, float]


class Counter:
    """Monotone accumulator (ints or float seconds/bytes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} is monotone; cannot inc by {n}")
        self.value += n


class Gauge:
    """Last-value metric that also tracks its peak."""

    __slots__ = ("name", "value", "peak")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0
        self.peak: Number = 0

    def set(self, v: Number) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v


class Histogram:
    """Stores raw observations; quantiles computed on demand (runs are
    small enough that reservoir sampling would only add noise)."""

    __slots__ = ("name", "values")

    def __init__(self, name: str) -> None:
        self.name = name
        self.values: List[Number] = []

    def observe(self, v: Number) -> None:
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> Number:
        return sum(self.values)

    def quantile(self, q: float) -> float:
        if not self.values:
            return 0.0
        xs = sorted(self.values)
        return float(xs[min(len(xs) - 1, int(q * len(xs)))])


class MetricsRegistry:
    """Get-or-create store of named metrics; name collisions across metric
    kinds raise instead of silently shadowing."""

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def hist(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- read side -----------------------------------------------------------

    def value(self, name: str, default: Number = 0) -> Number:
        m = self._metrics.get(name)
        if isinstance(m, (Counter, Gauge)):
            return m.value
        return default

    def peak(self, name: str, default: Number = 0) -> Number:
        m = self._metrics.get(name)
        if isinstance(m, Gauge):
            return m.peak
        return default

    def hist_values(self, name: str) -> List[Number]:
        m = self._metrics.get(name)
        return list(m.values) if isinstance(m, Histogram) else []

    def counters_under(self, prefix: str) -> Dict[str, Number]:
        return {n[len(prefix):]: m.value
                for n, m in sorted(self._metrics.items())
                if n.startswith(prefix) and isinstance(m, Counter)}

    def as_dict(self) -> Dict[str, Dict[str, object]]:
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = {"value": m.value, "peak": m.peak}
            elif isinstance(m, Histogram):
                out["histograms"][name] = {
                    "count": m.count, "total": m.total,
                    "p50": m.quantile(0.50), "p95": m.quantile(0.95)}
        return out
