"""Predicted-vs-measured: join the roofline byte model against live counters.

``analysis/roofline.paged_step_kv_bytes`` predicts the HBM KV traffic of one
paged decode step from shapes alone.  The instrumented scheduler now counts
the *measured* side — for the fused kernel, bytes derived from the block
tables actually staged each step (``PagedRowCache.step_tables`` records how
many live blocks it laid out); for the three-phase fallback, the dense
round-trip model evaluated at the step's true geometry.  This module joins
the two into a ratio the benches assert (fused decode must land within
1.25x of the model) and a table ``analysis/report.py`` renders.

The prediction is *per-row at the workload's expected row length*, scaled by
the measured average row occupancy.  Occupancy is an observable of the
arrival process (how full the batch ran), not of byte accounting, so using
the measured value does not make the comparison circular: the model's job
is to predict bytes *given* a step shape, and the block tables are free to
disagree with it (e.g. if stale rows or partial pages were accounted
wrongly, the ratio drifts out of bounds).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.analysis.roofline import paged_step_kv_bytes_for_pool

from .registry import MetricsRegistry


def fused_step_kv_bytes_measured(pool, blocks_live: int,
                                 rows_live: int) -> int:
    """Measured-side fused-step bytes from the block tables actually staged:
    each live block streams once at storage width, each live row writes one
    token back — ``2 * n_layers * (...)`` for K+V, same widths the model
    reads off the pool."""
    import jax.numpy as jnp
    scale_b = (0 if pool.k_scale is None
               else jnp.dtype(pool.k_scale.dtype).itemsize)
    vec_store = pool.cfg.num_kv_heads * (
        pool.cfg.head_dim * jnp.dtype(pool.storage_dtype).itemsize + scale_b)
    page_read = blocks_live * pool.block_size * vec_store
    token_write = rows_live * vec_store
    return 2 * pool.n_layers * (page_read + token_write)


def predicted_vs_measured(reg: MetricsRegistry, *, pool, buf_size: int,
                          expected_row_tokens: int,
                          fused: bool = True) -> Dict[str, Any]:
    """Join the roofline model against the run's measured per-step KV bytes.

    ``expected_row_tokens`` is the workload's expected tokens per live row
    (doc chunks + prompt + half the decode budget); the model is evaluated
    for one such row and scaled by the measured mean rows-per-step.
    Returns a dict with both sides, the ratio, and the raw counters.
    """
    steps = int(reg.value("decode.steps"))
    row_steps = int(reg.value("decode.row_steps"))
    measured_total = reg.value("decode.kv_bytes_measured")
    stale_total = reg.value("decode.kv_bytes_stale")
    if steps == 0:
        return {"steps": 0, "predicted_step_bytes": 0.0,
                "measured_step_bytes": 0.0, "ratio": 0.0,
                "occupancy": 0.0, "stale_step_bytes": 0.0, "fused": fused,
                "expected_row_tokens": expected_row_tokens}
    occupancy = row_steps / steps
    per_row = paged_step_kv_bytes_for_pool(
        pool, [expected_row_tokens], buf_size=buf_size, fused=fused)
    predicted = per_row * occupancy
    measured = measured_total / steps
    return {
        "steps": steps,
        "occupancy": occupancy,
        "expected_row_tokens": expected_row_tokens,
        "fused": fused,
        "predicted_step_bytes": float(predicted),
        "measured_step_bytes": float(measured),
        "stale_step_bytes": float(stale_total / steps),
        "ratio": float(measured / predicted) if predicted else 0.0,
    }


def comparison_table(rows) -> str:
    """Markdown table over ``predicted_vs_measured`` dicts tagged with a
    ``name`` key (what ``analysis/report.py`` renders)."""
    lines = [
        "| run | steps | occ | predicted B/step | measured B/step "
        "| ratio | stale B/step |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r.get('name', '?')} | {r['steps']} | {r['occupancy']:.2f} "
            f"| {r['predicted_step_bytes']:,.0f} "
            f"| {r['measured_step_bytes']:,.0f} | {r['ratio']:.3f} "
            f"| {r['stale_step_bytes']:,.0f} |")
    return "\n".join(lines)
