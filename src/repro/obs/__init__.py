"""Observability plane: tracing, metrics registry, model-vs-measured joins.

Zero-dependency (stdlib only) so every layer — pool, loader, queue,
scheduler, role workers, CLI — can import it unconditionally.  See
DESIGN.md §15 for the span taxonomy and role-merge semantics.
"""

from .compare import (comparison_table, fused_step_kv_bytes_measured,
                      predicted_vs_measured)
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import (NULL_TRACER, TRACE_SCHEMA, Tracer, arg_values,
                    load_chrome, merge_chrome, span_overlap_frac,
                    validate_chrome)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "TRACE_SCHEMA", "Tracer", "arg_values", "load_chrome",
    "merge_chrome", "span_overlap_frac", "validate_chrome",
    "comparison_table", "fused_step_kv_bytes_measured",
    "predicted_vs_measured",
]
