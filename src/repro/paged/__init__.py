# Paged KV subsystem: chunk-shared, ref-counted GPU block pool + page-table
# decode (DESIGN.md §10). One HBM copy of a chunk's KV serves every
# concurrent row that retrieved it; only each row's prompt/decode tail is
# private.
from repro.paged.pool import PagedKvPool, PoolStats
from repro.paged.runtime import (PagedRowCache, RowPages, gather_rows,
                                 scatter_decode_token, scatter_row_range)

__all__ = ["PagedKvPool", "PoolStats", "PagedRowCache", "RowPages",
           "gather_rows", "scatter_decode_token", "scatter_row_range"]
