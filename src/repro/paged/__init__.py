# Paged KV subsystem: chunk-shared, ref-counted GPU block pool + page-table
# decode (DESIGN.md §10). One HBM copy of a chunk's KV serves every
# concurrent row that retrieved it; only each row's prompt/decode tail is
# private. Pools are codec-aware (DESIGN.md §11): an Int8Codec pool stores
# int8 pages + f16 scales and widens on-chip in the fused gather/dequant op.
from repro.paged.pool import PagedKvPool, PoolStats
from repro.paged.runtime import (PagedRowCache, RowPages, gather_rows,
                                 gather_rows_quant, scatter_decode_token,
                                 scatter_decode_token_quant,
                                 scatter_row_range, scatter_row_range_quant)

__all__ = ["PagedKvPool", "PoolStats", "PagedRowCache", "RowPages",
           "gather_rows", "gather_rows_quant", "scatter_decode_token",
           "scatter_decode_token_quant", "scatter_row_range",
           "scatter_row_range_quant"]
