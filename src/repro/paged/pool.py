"""Ref-counted, chunk-shared GPU block pool for materialized KV pages.

The serving path before this subsystem reused chunk KVs only on *flash*:
every in-flight request owned a private GPU copy of each retrieved chunk
inside its ``RowAttnCache`` row, and N concurrent requests retrieving the
same hot chunk issued N independent flash reads. The pool extends the
paper's materialize-once/reuse-many story from flash to HBM:

* KV lives in flat device arrays ``k`` / ``v`` of shape
  ``(L, n_blocks * block_size, KV, hd)`` **in the pool codec's storage
  dtype** (DESIGN.md §11): a ``Bf16Codec`` pool holds activation-width
  values exactly as before; an ``Int8Codec`` pool holds int8 values plus
  f16 per-vector scale tensors ``k_scale`` / ``v_scale`` of shape
  ``(L, n_blocks * block_size, KV)``, so one HBM byte budget holds ~2x the
  resident chunks. Blocks of ``block_size`` token slots are the allocation
  unit; the layer axis is folded into the block tensors, so one block id
  covers a token range across every layer (the page key is logically
  ``(chunk_id, layer)`` — physically all layers of a token range share the
  id).
* A chunk's pages are inserted once (``insert``) and shared by every row
  that retrieved it (``acquire`` increments the refcount). ``release``
  decrements; at zero the pages are NOT freed — they move to a reclaim
  LRU so the next request for a hot chunk is an HBM hit with zero flash
  bytes. The free-list reclaims LRU pages only under allocation pressure.
* Private (copy-on-write tail) blocks for a row's prompt/decode tokens are
  allocated with ``alloc_private`` and returned with ``free_private`` —
  they are never shared and never enter the LRU. In a quantized pool the
  tail is stored quantized too (the scatter ops encode per-vector), exactly
  like production paged caches with a narrow kv_cache_dtype.

Host-side control plane is plain Python (deterministic, unit-testable);
only the block tensors live on device. Single-writer discipline: the
serving loop owns all mutations (the scheduler admits/evicts on one
thread), so there is no lock.

Under a serving mesh (DESIGN.md §12) the block tensors are laid out
KV-head-sharded through the ``repro.dist`` rule machinery: each device
holds every page for 1/N of the heads, so residency per device drops N×
while block ids, refcounts and the free list stay global host state (the
folded (layer, slot) axes never shard — a block id must mean the same
token range on every shard). ``device_bytes_per_shard`` /
``pinned_bytes_per_shard`` expose the per-shard accounting, which sums to
the single-device totals by construction.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.quantize import EncodedKV, KvCodec, get_codec
from repro.dist.sharding import spec_for

# Logical axes of the flat block tensors (L, n_slots, KV, hd) / scale
# tensors (L, n_slots, KV). Only the KV-head axis ever shards: the folded
# (layer, slot) axes must mean the same token range on every device shard,
# or block ids would name different pages per device.
_BLOCK_AXES = (None, None, "kv_heads", None)
_SCALE_AXES = (None, None, "kv_heads")


@dataclass
class PoolStats:
    chunk_hits: int = 0        # acquire() found the chunk HBM-resident
    chunk_misses: int = 0      # insert() had to write pages (flash was read)
    flash_bytes_loaded: int = 0  # payload bytes behind the misses
    reclaims: int = 0          # refcount-0 entries evicted for new pages
    demotions: int = 0         # reclaimed entries packed into the host tier
    promotions: int = 0        # host-tier entries rehydrated (zero flash)
    peak_used_blocks: int = 0  # allocated (incl. reclaimable LRU pages)
    peak_pinned_blocks: int = 0  # required working set: refs>0 + private
    peak_resident_chunks: int = 0  # distinct chunks with pages in the pool

    @property
    def hit_rate(self) -> float:
        total = self.chunk_hits + self.chunk_misses
        return self.chunk_hits / total if total else 0.0


@dataclass
class _ChunkPages:
    block_ids: List[int]
    n_tokens: int
    nbytes: int = 0            # serialized payload size (compose accounting)
    refs: int = 0


@dataclass
class _StreamEntry:
    """An in-flight block-granular insert: pages allocated up front, written
    a token block at a time as flash reads land. Invisible to ``has`` /
    ``acquire`` until ``commit_stream`` — the frontier is the only window
    into it (DESIGN.md §16)."""
    block_ids: List[int]
    n_tokens: int              # total expected
    n_resident: int = 0        # resident frontier: tokens written so far
    nbytes: int = 0            # encoded bytes accumulated


class PagedKvPool:
    """Fixed-size KV block pool with ref-counted, chunk-keyed shared pages."""

    def __init__(self, cfg: Any, n_blocks: int, block_size: int = 64,
                 n_layers: Optional[int] = None, dtype: Any = None,
                 codec: Union[str, KvCodec, None] = None,
                 mesh: Any = None, rules: Optional[dict] = None,
                 host_tier: Any = None) -> None:
        if n_blocks <= 0 or block_size <= 0:
            raise ValueError("PagedKvPool: n_blocks and block_size must be "
                             "positive")
        self.cfg = cfg
        self.block_size = int(block_size)
        self.n_blocks = int(n_blocks)
        self.n_layers = n_layers or cfg.num_layers
        self.codec = get_codec(codec)
        # dtype of the *decoded view* the model consumes; storage dtype is
        # the codec's (same thing for the passthrough codec)
        self.dtype = dtype or jnp.dtype(cfg.activation_dtype)
        self.storage_dtype = jnp.dtype(self.codec.storage_dtype or self.dtype)
        # tensor parallelism (DESIGN.md §12): with a mesh, the block tensors
        # are laid out KV-head-sharded via the repro.dist rule machinery.
        # All host-side control plane (free list, refcounts, block ids) stays
        # global — every device holds the same pages for ITS heads, so one
        # allocator drives all shards.
        self.mesh = mesh
        self._rules = rules

        def place(arr: jax.Array, names: Sequence[Optional[str]]
                  ) -> jax.Array:
            if mesh is None:
                return arr
            spec = spec_for(mesh, arr.shape, names, rules)
            return jax.device_put(arr, NamedSharding(mesh, spec))

        n_slots = self.n_blocks * self.block_size
        shape = (self.n_layers, n_slots, cfg.num_kv_heads, cfg.head_dim)
        self.k = place(jnp.zeros(shape, self.storage_dtype), _BLOCK_AXES)
        self.v = place(jnp.zeros(shape, self.storage_dtype), _BLOCK_AXES)
        self.k_scale: Optional[jax.Array]
        self.v_scale: Optional[jax.Array]
        if self.codec.scale_dtype is not None:
            sshape = (self.n_layers, n_slots, cfg.num_kv_heads)
            self.k_scale = place(jnp.zeros(sshape, self.codec.scale_dtype),
                                 _SCALE_AXES)
            self.v_scale = place(jnp.zeros(sshape, self.codec.scale_dtype),
                                 _SCALE_AXES)
        else:
            self.k_scale = self.v_scale = None
        self.stats = PoolStats()
        # observability: schedulers attach their tracer post-construction
        from repro.obs import NULL_TRACER
        self.tracer = NULL_TRACER
        self._free: List[int] = list(range(self.n_blocks))
        self._entries: Dict[str, _ChunkPages] = {}
        self._lru: "OrderedDict[str, None]" = OrderedDict()  # refs == 0
        self._pinned_blocks = 0
        self._private: Set[int] = set()  # outstanding alloc_private ids
        self._streams: Dict[str, _StreamEntry] = {}
        # host-DRAM mid-tier (DESIGN.md §16): refs-0 pages reclaimed under
        # allocation pressure demote into this bounded byte cache instead of
        # dropping, and re-promotion rehydrates them with ZERO flash bytes.
        # Accepts a capacity in bytes or a ready-made LruBytesCache.
        if host_tier is None or isinstance(host_tier, int):
            from repro.kvstore.cache_tier import LruBytesCache
            self.host_tier = (LruBytesCache(host_tier) if host_tier
                              else None)
        else:
            self.host_tier = host_tier

    # -- sizing ----------------------------------------------------------------
    @staticmethod
    def block_bytes(cfg: Any, block_size: int = 64,
                    codec: Union[str, KvCodec, None] = None,
                    n_layers: Optional[int] = None) -> int:
        """Encoded HBM bytes of one block (K + V + scales) — usable before a
        pool exists, e.g. to size ``n_blocks`` from a byte budget."""
        codec = get_codec(codec)
        act = jnp.dtype(cfg.activation_dtype).itemsize
        return (2 * (n_layers or cfg.num_layers) * block_size
                * cfg.num_kv_heads * codec.bytes_per_vector(cfg.head_dim, act))

    @classmethod
    def blocks_for_budget(cls, cfg: Any, budget_bytes: int,
                          block_size: int = 64,
                          codec: Union[str, KvCodec, None] = None,
                          n_layers: Optional[int] = None) -> int:
        """How many blocks one HBM byte budget buys under ``codec`` — the
        equal-budget comparison the quantized-residency benchmark runs."""
        per = cls.block_bytes(cfg, block_size, codec, n_layers)
        return max(1, int(budget_bytes) // per)

    @property
    def bytes_per_block(self) -> int:
        # from the pool's actual view dtype (which may override
        # cfg.activation_dtype), not the static cfg-derived estimate
        return (2 * self.n_layers * self.block_size * self.cfg.num_kv_heads
                * self.codec.bytes_per_vector(self.cfg.head_dim,
                                              self.dtype.itemsize))

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def resident_bytes(self) -> int:
        """HBM KV bytes behind allocated (shared + private) blocks."""
        return self.used_blocks * self.bytes_per_block

    @property
    def resident_chunks(self) -> int:
        """Distinct chunks with pages in the pool (pinned or reclaimable)."""
        return len(self._entries)

    @property
    def pinned_blocks(self) -> int:
        """Blocks the pool cannot reclaim: refs>0 chunk pages + private
        allocations. Refcount-0 LRU pages are an opportunistic hot-set cache
        (reclaimed on demand) and don't count against required residency."""
        return self._pinned_blocks

    @property
    def pinned_bytes(self) -> int:
        return self._pinned_blocks * self.bytes_per_block

    def _pin(self, n: int) -> None:
        self._pinned_blocks += n
        self.stats.peak_pinned_blocks = max(self.stats.peak_pinned_blocks,
                                            self._pinned_blocks)

    @property
    def capacity_bytes(self) -> int:
        return self.n_blocks * self.bytes_per_block

    # -- per-shard accounting ----------------------------------------------------
    @property
    def n_kv_shards(self) -> int:
        """Device shards the KV-head axis is split over: 1 without a mesh,
        or when the head count doesn't divide the mesh axis (the
        divisibility-aware rules fall back to replication)."""
        if self.mesh is None:
            return 1
        axes = spec_for(self.mesh, self.k.shape, _BLOCK_AXES, self._rules)[2]
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return math.prod(self.mesh.shape[a] for a in axes)

    @property
    def pinned_bytes_per_shard(self) -> int:
        """Each device shard's slice of the required working set — the
        HBM-capacity relief of sharding the pool: per-shard residency is
        ``pinned_bytes / n_kv_shards``, and the shard totals sum back to the
        single-device figure."""
        return self.pinned_bytes // self.n_kv_shards

    def device_bytes_per_shard(self) -> List[int]:
        """Ground-truth HBM bytes of the block (+ scale) tensors held on
        each device, read off the actual device buffers. Sums to the
        single-device pool footprint regardless of mesh shape — the
        accounting invariant tests/benchmarks assert."""
        tensors = [self.k, self.v]
        if self.k_scale is not None:
            tensors += [self.k_scale, self.v_scale]
        per: Dict[object, int] = {}
        for t in tensors:
            for s in t.addressable_shards:
                per[s.device] = per.get(s.device, 0) + s.data.nbytes
        return [per[d] for d in sorted(per, key=str)]

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    # -- allocation ------------------------------------------------------------
    def _alloc(self, n: int) -> List[int]:
        while len(self._free) < n and self._lru:
            victim, _ = self._lru.popitem(last=False)
            pages = self._entries.pop(victim)
            if self.host_tier is not None:
                # demote before the blocks are recycled: the victim's KV
                # survives as host bytes, so the next request for it skips
                # flash entirely (promote() rehydrates)
                self._demote(victim, pages)
            self._free.extend(pages.block_ids)
            self.stats.reclaims += 1
            self.tracer.instant("pool_reclaim", chunk=victim,
                                blocks=len(pages.block_ids))
        if len(self._free) < n:
            raise RuntimeError(
                f"PagedKvPool exhausted: need {n} blocks, "
                f"{len(self._free)} free of {self.n_blocks} "
                f"(pinned chunks: "
                f"{sum(1 for e in self._entries.values() if e.refs)}); "
                f"size the pool larger")
        out, self._free = self._free[:n], self._free[n:]
        self.stats.peak_used_blocks = max(self.stats.peak_used_blocks,
                                          self.used_blocks)
        return out

    def alloc_private(self, n_slots: int) -> List[int]:
        """Allocate private (COW-tail) blocks covering ``n_slots`` tokens."""
        out = self._alloc(self.blocks_for(max(1, n_slots)))
        self._private.update(out)
        self._pin(len(out))
        return out

    def free_private(self, block_ids: Sequence[int]) -> None:
        """Return private blocks to the free list. Only blocks currently
        outstanding from ``alloc_private`` are accepted: a double free (or a
        shared chunk's block ids) would put duplicate ids on the free list,
        and two later allocations would silently alias one page — corrupting
        co-resident requests' KV."""
        ids = list(block_ids)
        bad = [b for b in ids if b not in self._private]
        if bad or len(set(ids)) != len(ids):
            raise ValueError(
                f"pool.free_private: blocks {bad or sorted(ids)} are not "
                f"outstanding private allocations (double free, or a shared "
                f"chunk's pages?) — duplicate free-list ids alias later "
                f"allocations and corrupt co-resident rows")
        self._private.difference_update(ids)
        self._free.extend(ids)
        self._pinned_blocks -= len(ids)

    # -- shared chunk pages ------------------------------------------------------
    def has(self, chunk_id: str) -> bool:
        return chunk_id in self._entries

    def acquire(self, chunk_id: str) -> Optional[int]:
        """Pin one more reference to a resident chunk; returns its token
        count, or None if the chunk has no pages in the pool."""
        pages = self._entries.get(chunk_id)
        if pages is None:
            return None
        pages.refs += 1
        if pages.refs == 1:                 # re-pinned out of the LRU
            self._lru.pop(chunk_id, None)
            self._pin(len(pages.block_ids))
        self.stats.chunk_hits += 1
        return pages.n_tokens

    def _encode_artifact(self, k_art: Any, v_art: Any
                         ) -> Tuple[jax.Array, jax.Array, Any, Any]:
        """Decoded (L, S, KV, hd) k/v -> storage tensors + scales (or None)."""
        k_enc, k_sc = self.codec.encode(k_art)
        v_enc, v_sc = self.codec.encode(v_art)
        return k_enc, v_enc, k_sc, v_sc

    def insert(self, chunk_id: str, k_art: Any = None, v_art: Any = None,
               nbytes: int = 0, *,
               encoded: Optional[EncodedKV] = None) -> int:
        """Write one chunk's KV artifact into freshly allocated pages with
        refcount 1; returns the token count. Two forms:

        * decoded ``k_art`` / ``v_art`` (``(L, 1, S, KV, hd)`` or
          ``(L, S, KV, hd)`` activation-width) — encoded here with the pool
          codec;
        * ``encoded=EncodedKV`` straight off flash — written through without
          widening when its codec matches the pool's (the int8 fast path),
          transcoded (decode -> re-encode) otherwise.

        The caller must have checked ``acquire`` first — double insert
        raises.
        """
        if chunk_id in self._entries:
            raise ValueError(f"pool.insert: {chunk_id!r} already resident "
                             f"(acquire it instead)")
        if chunk_id in self._streams:
            raise ValueError(f"pool.insert: {chunk_id!r} is streaming in "
                             f"(commit_stream it instead)")
        if encoded is not None:
            k_enc, v_enc, k_sc, v_sc = self._encode_for_write(encoded)
        else:
            if k_art.ndim == 5:
                k_art, v_art = k_art[:, 0], v_art[:, 0]
            k_enc, v_enc, k_sc, v_sc = self._encode_artifact(k_art, v_art)
        n_tokens = int(k_enc.shape[1])
        with self.tracer.span("pool_insert", chunk=chunk_id,
                              tokens=n_tokens):
            blocks = self._alloc(self.blocks_for(n_tokens))
            slots = self.token_slot_ids(blocks, n_tokens)
            self._write_slots(slots, k_enc, v_enc, k_sc, v_sc)
        self._entries[chunk_id] = _ChunkPages(block_ids=blocks,
                                              n_tokens=n_tokens,
                                              nbytes=nbytes, refs=1)
        self._pin(len(blocks))
        self.stats.chunk_misses += 1
        self.stats.flash_bytes_loaded += nbytes
        self.stats.peak_resident_chunks = max(self.stats.peak_resident_chunks,
                                              len(self._entries))
        return n_tokens

    def _encode_for_write(self, encoded: EncodedKV
                          ) -> Tuple[jax.Array, jax.Array, Any, Any]:
        """``EncodedKV`` -> storage-form tensors: write-through when its
        codec matches the pool's, decode -> re-encode transcode otherwise."""
        k_enc, v_enc = jnp.asarray(encoded.k), jnp.asarray(encoded.v)
        if encoded.codec.codec_id == self.codec.codec_id:
            k_sc = (None if encoded.k_scale is None
                    else jnp.asarray(encoded.k_scale))
            v_sc = (None if encoded.v_scale is None
                    else jnp.asarray(encoded.v_scale))
            return k_enc, v_enc, k_sc, v_sc
        return self._encode_artifact(
            encoded.codec.decode(k_enc, encoded.k_scale, self.dtype),
            encoded.codec.decode(v_enc, encoded.v_scale, self.dtype))

    def _write_slots(self, slots: np.ndarray, k_enc: jax.Array,
                     v_enc: jax.Array, k_sc: Any, v_sc: Any) -> None:
        """Write encoded (L, t, KV, hd) tensors into pool slots ``slots``."""
        self.k = self.k.at[:, slots].set(k_enc.astype(self.storage_dtype))
        self.v = self.v.at[:, slots].set(v_enc.astype(self.storage_dtype))
        if self.k_scale is not None:
            sd = self.codec.scale_dtype
            self.k_scale = self.k_scale.at[:, slots].set(
                jnp.asarray(k_sc)[..., 0].astype(sd))
            self.v_scale = self.v_scale.at[:, slots].set(
                jnp.asarray(v_sc)[..., 0].astype(sd))

    # -- streaming inserts (resident frontier, DESIGN.md §16) -------------------
    def begin_stream(self, chunk_id: str, n_tokens: int) -> None:
        """Reserve pages for a chunk whose blocks will arrive incrementally.
        The reserved blocks are neither free nor reclaimable (not in the
        LRU), so racing allocations can never recycle a page mid-stream; the
        entry stays invisible to ``has``/``acquire`` until committed."""
        if chunk_id in self._entries or chunk_id in self._streams:
            raise ValueError(f"pool.begin_stream: {chunk_id!r} already "
                             f"resident or streaming")
        blocks = self._alloc(self.blocks_for(n_tokens))
        self._pin(len(blocks))
        self._streams[chunk_id] = _StreamEntry(block_ids=blocks,
                                               n_tokens=n_tokens)

    def extend_stream(self, chunk_id: str, encoded: EncodedKV,
                      t0: int, t1: int, nbytes: int = 0) -> int:
        """Write token block [t0, t1) of a streaming chunk; blocks must
        arrive in order (t0 == current frontier). Returns the new frontier."""
        entry = self._streams[chunk_id]
        if t0 != entry.n_resident or t1 > entry.n_tokens:
            raise ValueError(
                f"pool.extend_stream: block [{t0},{t1}) does not extend "
                f"frontier {entry.n_resident}/{entry.n_tokens} "
                f"of {chunk_id!r}")
        k_enc, v_enc, k_sc, v_sc = self._encode_for_write(encoded)
        slots = self.token_slot_ids(entry.block_ids, entry.n_tokens)[t0:t1]
        self._write_slots(slots, k_enc, v_enc, k_sc, v_sc)
        entry.n_resident = t1
        entry.nbytes += nbytes
        self.tracer.instant("frontier_advance", chunk=chunk_id,
                            tokens=t1, total=entry.n_tokens)
        return entry.n_resident

    def commit_stream(self, chunk_id: str) -> int:
        """Promote a fully-arrived stream into a normal refcount-1 entry
        (the moment it becomes visible to ``has``/``acquire``)."""
        entry = self._streams[chunk_id]
        if entry.n_resident != entry.n_tokens:
            raise ValueError(
                f"pool.commit_stream: {chunk_id!r} frontier at "
                f"{entry.n_resident}/{entry.n_tokens}")
        del self._streams[chunk_id]
        self._entries[chunk_id] = _ChunkPages(block_ids=entry.block_ids,
                                              n_tokens=entry.n_tokens,
                                              nbytes=entry.nbytes, refs=1)
        # blocks were pinned at begin_stream; this is the flash miss the
        # stream serviced
        self.stats.chunk_misses += 1
        self.stats.flash_bytes_loaded += entry.nbytes
        self.stats.peak_resident_chunks = max(self.stats.peak_resident_chunks,
                                              len(self._entries))
        return entry.n_tokens

    def abort_stream(self, chunk_id: str) -> None:
        """Tear down a failed/abandoned stream; its pages return to the
        free list."""
        entry = self._streams.pop(chunk_id, None)
        if entry is None:
            return
        self._free.extend(entry.block_ids)
        self._pinned_blocks -= len(entry.block_ids)

    def stream_frontier(self, chunk_id: str) -> Optional[int]:
        """Tokens resident for an in-flight stream, or None if not
        streaming."""
        entry = self._streams.get(chunk_id)
        return entry.n_resident if entry is not None else None

    def chunk_tokens(self, chunk_id: str) -> Optional[int]:
        """Token count of a resident or streaming chunk (None if absent)."""
        if chunk_id in self._entries:
            return self._entries[chunk_id].n_tokens
        entry = self._streams.get(chunk_id)
        return entry.n_tokens if entry is not None else None

    # -- host-DRAM demotion tier (DESIGN.md §16) --------------------------------
    def _demote(self, chunk_id: str, pages: _ChunkPages) -> None:
        """Pack a reclaimed entry's pages into the host tier (encoded
        storage form, so the host budget prices exactly like flash)."""
        from repro.kvstore.serialization import serialize
        slots = self.token_slot_ids(pages.block_ids, pages.n_tokens)
        tensors = {"k": np.asarray(self.k[:, slots]),
                   "v": np.asarray(self.v[:, slots])}
        if self.k_scale is not None:
            tensors["k.scale"] = np.asarray(self.k_scale[:, slots])
            tensors["v.scale"] = np.asarray(self.v_scale[:, slots])
        payload = serialize(tensors, meta={"n_tokens": pages.n_tokens,
                                           "nbytes": pages.nbytes,
                                           "codec": self.codec.codec_id})
        self.host_tier.put(chunk_id, payload)
        self.stats.demotions += 1
        self.tracer.instant("pool_demote", chunk=chunk_id,
                            bytes=len(payload))

    def host_has(self, chunk_id: str) -> bool:
        """Whether the host tier holds a demoted copy (recency untouched)."""
        return (self.host_tier is not None
                and self.host_tier.contains(chunk_id))

    def promote(self, chunk_id: str) -> Optional[int]:
        """Rehydrate a demoted chunk from host bytes into fresh pages with
        refcount 1 — ZERO flash bytes. Returns its token count, or None when
        the host tier has no copy. The caller must have checked ``acquire``
        first, exactly like ``insert``."""
        if self.host_tier is None:
            return None
        payload = self.host_tier.get(chunk_id)
        if payload is None:
            return None
        if chunk_id in self._entries or chunk_id in self._streams:
            raise ValueError(f"pool.promote: {chunk_id!r} already resident "
                             f"or streaming")
        from repro.kvstore.serialization import deserialize
        tensors, meta = deserialize(payload)
        k_sc = tensors.get("k.scale")
        v_sc = tensors.get("v.scale")
        n_tokens = int(meta["n_tokens"])
        with self.tracer.span("pool_promote", chunk=chunk_id,
                              tokens=n_tokens):
            blocks = self._alloc(self.blocks_for(n_tokens))
            slots = self.token_slot_ids(blocks, n_tokens)
            # stored in the pool's own storage form — write straight through
            # (scales are already (L, t, KV); _write_slots expects the
            # artifact's trailing-1 axis)
            self._write_slots(slots, jnp.asarray(tensors["k"]),
                              jnp.asarray(tensors["v"]),
                              None if k_sc is None else
                              jnp.asarray(k_sc)[..., None],
                              None if v_sc is None else
                              jnp.asarray(v_sc)[..., None])
        self._entries[chunk_id] = _ChunkPages(block_ids=blocks,
                                              n_tokens=n_tokens,
                                              nbytes=int(meta["nbytes"]),
                                              refs=1)
        self._pin(len(blocks))
        self.stats.promotions += 1
        self.stats.peak_resident_chunks = max(self.stats.peak_resident_chunks,
                                              len(self._entries))
        return n_tokens

    def release(self, chunk_id: str) -> None:
        """Drop one reference. At zero the pages stay resident (HBM cache of
        the hot set) but become reclaimable, LRU-first."""
        pages = self._entries.get(chunk_id)
        if pages is None or pages.refs <= 0:
            raise ValueError(f"pool.release: {chunk_id!r} not acquired")
        pages.refs -= 1
        if pages.refs == 0:
            self._lru[chunk_id] = None
            self._lru.move_to_end(chunk_id)
            self._pinned_blocks -= len(pages.block_ids)

    def refcount(self, chunk_id: str) -> int:
        pages = self._entries.get(chunk_id)
        return pages.refs if pages is not None else 0

    def drop_if_unreferenced(self, chunk_id: str) -> bool:
        """Eagerly evict a refcount-0 entry (its blocks return to the free
        list); False if absent or still referenced. The stale-generation
        path (DESIGN.md §14): a decode worker drops a superseded
        ``cid@gN`` entry the moment it installs ``cid@gN+1``, instead of
        letting dead pages squat in the LRU until pressure reclaims them.
        Rows still decoding against the old generation hold refs, so their
        pages are never pulled out from under them."""
        pages = self._entries.get(chunk_id)
        if pages is None or pages.refs > 0:
            return False
        self._lru.pop(chunk_id, None)
        self._entries.pop(chunk_id)
        self._free.extend(pages.block_ids)
        return True

    # -- slot arithmetic -----------------------------------------------------------
    def token_slot_ids(self, block_ids: Sequence[int],
                       n_tokens: int) -> np.ndarray:
        """Flat pool-slot index of each of the first ``n_tokens`` token slots
        covered by ``block_ids`` (partial final block: trailing slots of the
        last block are simply never referenced)."""
        base = np.repeat(np.asarray(block_ids, np.int64), self.block_size)
        off = np.tile(np.arange(self.block_size, dtype=np.int64),
                      len(block_ids))
        return (base * self.block_size + off)[:n_tokens].astype(np.int32)

    def chunk_slot_ids(self, chunk_id: str) -> np.ndarray:
        pages = self._entries[chunk_id]
        return self.token_slot_ids(pages.block_ids, pages.n_tokens)

    def chunk_payload_bytes(self, chunk_id: str) -> int:
        return self._entries[chunk_id].nbytes
