"""Page-table serving runtime: per-row gather tables over the block pool.

``PagedRowCache`` replaces the dense per-slot ``RowAttnCache`` of the
continuous scheduler with page-table indirection: each decode slot carries a
*gather table* ``gather_idx (B, S_buf) int32`` mapping the row's dense
(logical) slot ``s`` to a flat pool slot. Slots ``[0, n_doc)`` map into the
shared, ref-counted chunk pages (one HBM copy per chunk, pool-wide); slots
``[n_doc, ...)`` map into the row's private copy-on-write tail blocks where
its prompt and generated tokens land.

The decode step is gather → step → scatter:

1. ``gather_rows`` materializes the dense ``RowAttnCache`` *view* of the
   page table (a device temporary; persistent HBM holds one copy per chunk).
   Because the gather compacts each row's valid tokens in retrieval order,
   the view is value-identical to what the row-slotted path would hold —
   the engine then runs the **same jitted ``decode_step_rows`` executable**
   on it, which is what makes paged answers bit-identical to the
   ``RowAttnCache`` path by construction.
2. ``scatter_decode_token`` writes the step's new K/V (one token per row,
   at each row's ``length % S_buf`` dense slot) back through the gather
   table into that row's private tail block. Active rows always land in
   their own tail; retired rows are remapped to a per-slot scratch block
   (``scratch_row``) so their dummy decode steps can never touch pages a
   live request shares.

Quantized pools (``Int8Codec``, DESIGN.md §11) swap both halves for fused
codec twins: ``gather_rows_quant`` widens int8 values by their f16 scales
*inside* the gather (the HBM-resident pages never widen), and the
``*_quant`` scatters re-encode new K/V per-vector on the way back in — the
decode tail is stored quantized like the chunk pages, exactly as a
production paged cache with a narrow kv_cache_dtype does. Values decoded
from shared pages are bit-identical to the dense int8 path's compose-time
dequantization (same scalar math); only tail tokens carry quantization
noise, bounded in tests.

Sharing chunk pages requires chunk K content to be position-independent,
i.e. the paper-faithful restarted-positions mode (``rerotate=False``); the
engine gates paged mode on it.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import quantize_kv
from repro.models.cache import RowAttnCache
from repro.paged.pool import PagedKvPool


@dataclass
class RowPages:
    """Host-side page-table handle for one decode slot."""
    chunk_refs: List[str] = field(default_factory=list)  # one entry per ref
    private_blocks: List[int] = field(default_factory=list)
    n_doc: int = 0
    tail_slots: Optional[np.ndarray] = None  # pool slots of the private tail


class PagedRowCache:
    """Page-table decode state for ``max_slots`` rows over one shared pool.

    Device state mirrors ``RowAttnCache`` exactly (``slot_pos (B, S_buf)``,
    ``length (B,)``) plus the gather table; KV bytes live in ``pool.k/v``
    (+ scale tensors for quantized pools) only. Host state tracks each
    slot's page handle for release. ``dense_view`` / ``scatter_*`` dispatch
    on the pool codec, so the engine is codec-blind.
    """

    def __init__(self, pool: PagedKvPool, max_slots: int, buf_size: int):
        self.pool = pool
        self.max_slots = max_slots
        self.buf_size = buf_size
        self.rows: List[RowPages] = [RowPages() for _ in range(max_slots)]
        # one permanent scratch block, shared by every slot: the write
        # target for dummy decode steps into stale (retired) rows. Stale
        # rows racing on one slot is fine — the values are garbage either
        # way and are masked by each row's slot_pos; what matters is that
        # stale writes can never land in pages a live request uses.
        # the scratch block is engine-lifetime by design (shared
        # dummy-write target); it is never freed.
        self._scratch = pool.alloc_private(1)[0]  # repro: noqa[RP101]
        gi = np.stack([self.scratch_row(s) for s in range(max_slots)])
        self.gather_idx = jnp.asarray(gi)
        self.slot_pos = jnp.full((max_slots, buf_size), -1, jnp.int32)
        self.length = jnp.zeros((max_slots,), jnp.int32)
        # host mirrors of the gather table and row lengths: the fused decode
        # path builds its per-step block tables from these without a device
        # round-trip (``step_tables``). ``host_lengths`` advances via
        # ``note_step`` (every batched step ages every slot, live or stale —
        # exactly like the device ``length``); absolute values re-sync at
        # admit time (``set_row_state``).
        self.host_gather = gi.copy()
        self.host_lengths = np.zeros((max_slots,), np.int64)

    @property
    def quantized(self) -> bool:
        return self.pool.k_scale is not None

    def scratch_row(self, slot: int) -> np.ndarray:
        """Gather row mapping every dense slot into the shared scratch block
        (cyclic): reads see masked garbage, writes land in scratch."""
        base = self._scratch * self.pool.block_size
        return (base + np.arange(self.buf_size) % self.pool.block_size
                ).astype(np.int32)

    # -- admit / retire ----------------------------------------------------------
    def install_row(self, slot: int, handle: RowPages,
                    gather_row: np.ndarray) -> None:
        self.rows[slot] = handle
        self.host_gather[slot] = gather_row
        self.gather_idx = self.gather_idx.at[slot].set(
            jnp.asarray(gather_row))

    def set_row_state(self, slot: int, slot_pos_row, length_row) -> None:
        """Mirror ``insert_cache_row`` for the slot's position state."""
        self.slot_pos = self.slot_pos.at[slot].set(slot_pos_row)
        self.length = self.length.at[slot].set(length_row)
        self.host_lengths[slot] = int(length_row)

    def release_row(self, slot: int) -> None:
        """Retire a slot: decref shared chunk pages (pages another request
        holds stay exactly where they are), free the private tail, and remap
        the slot's writes to scratch. Position state stays stale (masked) —
        same lifecycle as the dense row-slotted path."""
        handle = self.rows[slot]
        for cid in handle.chunk_refs:
            self.pool.release(cid)
        self.pool.free_private(handle.private_blocks)
        self.rows[slot] = RowPages()
        scratch = self.scratch_row(slot)
        self.host_gather[slot] = scratch
        self.gather_idx = self.gather_idx.at[slot].set(jnp.asarray(scratch))

    def resident_frontier(self, chunk_keys: List[str]) -> int:
        """Resident-prefix length (tokens) across a row's retrieval-ordered
        chunks: committed/resident chunks count fully, an in-flight stream
        counts up to its frontier, and the walk stops at the first gap —
        this is the prefix streaming admission may attend over while
        ``AsyncKvLoader`` races the tail blocks in (DESIGN.md §16)."""
        total = 0
        for key in chunk_keys:
            n = self.pool.chunk_tokens(key)
            if n is None:
                break
            f = self.pool.stream_frontier(key)
            if f is not None:                  # still streaming
                total += f
                if f < n:
                    break
            else:
                total += n
        return total

    def note_step(self) -> None:
        """Age every slot by one decode token (the host mirror of the device
        ``length + 1`` a batched step performs for live AND stale rows)."""
        self.host_lengths += 1

    # -- fused-step block tables ---------------------------------------------------
    def step_tables(self, bucket: int = 4):
        """Build the fused kernel's per-row block tables for the NEXT decode
        step from the host gather mirror: each row's dense prefix [0, length)
        compresses into (pool block id, valid token count) runs — every run
        starts at block offset 0 because ``token_slot_ids`` lays chunks and
        tails out block-aligned (and ``scratch_row`` is block-cyclic).

        ``bucket`` rounds the table width up (retrace bound for the jitted
        fused step: one trace per width bucket, not per occupancy pattern).

        Raises ValueError when a live row's append would land outside its
        private tail — the shared-page mutation guard: past that point the
        dense path would wrap ``length % buf`` into slots mapping to
        ref-counted chunk pages, and an in-place append would corrupt every
        co-resident row sharing them. (Stale/retired rows are exempt: their
        writes are scratch-mapped and their logits are discarded.)

        Returns (tables (B, n_max) int32, lens (B, n_max) int32,
        totals (B,) int32, n_max).
        """
        bs = self.pool.block_size
        totals = np.clip(self.host_lengths + 1, 1,
                         self.buf_size).astype(np.int32)
        per_row = []
        for slot in range(self.max_slots):
            handle = self.rows[slot]
            length = int(self.host_lengths[slot])
            if handle.tail_slots is not None:
                cap = handle.n_doc + len(handle.tail_slots)
                if length + 1 > cap:
                    raise ValueError(
                        f"step_tables: slot {slot} append at length {length} "
                        f"exceeds its private tail (n_doc {handle.n_doc} + "
                        f"tail {len(handle.tail_slots)}); appending past the "
                        f"tail would write into ref-counted shared pages — "
                        f"admit rows with max_new_tokens covered by the tail")
            g = self.host_gather[slot]
            span = int(totals[slot]) - 1        # prior tokens to attend over
            entries = []
            p = 0
            while p < span:
                s = int(g[p])
                blk, off = divmod(s, bs)
                if off:
                    raise ValueError(
                        f"step_tables: slot {slot} gather row is not "
                        f"block-aligned at dense slot {p} (pool slot {s}) — "
                        f"pages must be laid out by token_slot_ids")
                n = min(bs, span - p)
                run = 1
                while run < n and int(g[p + run]) == s + run:
                    run += 1
                entries.append((blk, run))
                p += run
            per_row.append(entries)
        # measured-bytes accounting (repro.obs.compare): how much KV the
        # fused kernel will actually stream this step. Live rows (installed
        # tail) are real traffic; stale slots keep stepping into scratch and
        # are reported separately so the roofline join stays honest.
        live = [self.rows[s].tail_slots is not None
                for s in range(self.max_slots)]
        self.last_step_stats = {
            "blocks_live": sum(len(e) for e, lv in zip(per_row, live) if lv),
            "blocks_stale": sum(len(e) for e, lv in zip(per_row, live)
                                if not lv),
            "rows_live": sum(live),
        }
        n_max = max((len(e) for e in per_row), default=0)
        n_max = max(1, -(-n_max // bucket) * bucket)
        tables = np.full((self.max_slots, n_max), self._scratch, np.int32)
        lens = np.zeros((self.max_slots, n_max), np.int32)
        for i, entries in enumerate(per_row):
            for j, (blk, run) in enumerate(entries):
                tables[i, j] = blk
                lens[i, j] = run
        return (jnp.asarray(tables), jnp.asarray(lens), jnp.asarray(totals),
                n_max)

    # -- dense views ---------------------------------------------------------------
    def _view(self, gather_idx, slot_pos, length) -> RowAttnCache:
        pool = self.pool
        if self.quantized:
            k, v = gather_rows_quant(pool.k, pool.v, pool.k_scale,
                                     pool.v_scale, gather_idx,
                                     dtype=pool.dtype)
        else:
            k, v = gather_rows(pool.k, pool.v, gather_idx)
        return RowAttnCache(k=k, v=v, slot_pos=slot_pos, length=length)

    def dense_view(self) -> RowAttnCache:
        return self._view(self.gather_idx, self.slot_pos, self.length)

    def dense_row_view(self, slot: int) -> RowAttnCache:
        return self._view(self.gather_idx[slot][None],
                          self.slot_pos[slot][None],
                          self.length[slot][None])

    # -- scatters (write-back through the page table) ------------------------------
    def scatter_step(self, prev_length, new_k, new_v) -> None:
        """Persist one batched decode step's new token per row into each
        row's private tail (scratch for stale rows), encoding per-vector on
        quantized pools."""
        pool = self.pool
        if self.quantized:
            pool.k, pool.v, pool.k_scale, pool.v_scale = (
                scatter_decode_token_quant(pool.k, pool.v, pool.k_scale,
                                           pool.v_scale, self.gather_idx,
                                           prev_length, new_k, new_v))
        else:
            pool.k, pool.v = scatter_decode_token(
                pool.k, pool.v, self.gather_idx, prev_length, new_k, new_v)

    def scatter_range(self, phys_idx, k_row, v_row, start) -> None:
        """Persist a batch=1 sub-prefill's new K/V range (the prompt tokens
        written at dense slots ``[start, start + len(phys_idx))``)."""
        pool = self.pool
        phys = jnp.asarray(phys_idx)
        start = jnp.asarray(start, jnp.int32)
        if self.quantized:
            pool.k, pool.v, pool.k_scale, pool.v_scale = (
                scatter_row_range_quant(pool.k, pool.v, pool.k_scale,
                                        pool.v_scale, phys, k_row, v_row,
                                        start))
        else:
            pool.k, pool.v = scatter_row_range(pool.k, pool.v, phys,
                                               k_row, v_row, start)


# ---------------------------------------------------------------------------
# jitted gather / scatter
# ---------------------------------------------------------------------------

@jax.jit
def gather_rows(pool_k, pool_v, gather_idx):
    """(L, N_slots, KV, hd) pool + (B, S_buf) table -> (L, B, S_buf, KV, hd)
    dense view. Table entries are taken literally (callers map padding slots
    to private/scratch blocks, whose values are masked by slot_pos)."""
    b, s = gather_idx.shape
    idx = gather_idx.reshape(-1)
    k = jnp.take(pool_k, idx, axis=1)
    v = jnp.take(pool_v, idx, axis=1)
    shape = (pool_k.shape[0], b, s) + pool_k.shape[2:]
    return k.reshape(shape), v.reshape(shape)


@functools.partial(jax.jit, static_argnames=("dtype",))
def gather_rows_quant(pool_k, pool_v, k_scale, v_scale, gather_idx,
                      dtype=jnp.bfloat16):
    """Fused gather + dequant: int8 pool (L, N_slots, KV, hd) + f16 scales
    (L, N_slots, KV) -> activation-width dense view. The per-element math is
    exactly ``dequantize_kv`` (f32 multiply, then cast), so values decoded
    from shared pages are bit-identical to the dense path's compose-time
    dequantization of the same artifact."""
    b, s = gather_idx.shape
    idx = gather_idx.reshape(-1)

    def deq(pool, scale):
        vals = jnp.take(pool, idx, axis=1).astype(jnp.float32)
        sc = jnp.take(scale, idx, axis=1).astype(jnp.float32)[..., None]
        return (vals * sc).astype(dtype)

    shape = (pool_k.shape[0], b, s) + pool_k.shape[2:]
    return deq(pool_k, k_scale).reshape(shape), \
        deq(pool_v, v_scale).reshape(shape)


def _token_at(new_kv, start):
    """Pick each row's new-token vector out of the dense step buffers:
    new_kv (L, B, S_buf, KV, hd), start (B,) -> (L, B, KV, hd)."""
    return jnp.take_along_axis(
        new_kv, start[None, :, None, None, None], axis=2)[:, :, 0]


@functools.partial(jax.jit, donate_argnums=(0, 1))
def scatter_decode_token(pool_k, pool_v, gather_idx, prev_length,
                         new_k, new_v):
    """Persist one decode step's new K/V (``new_k/v (L, B, S_buf, KV, hd)``,
    the dense buffers returned by ``decode_step_rows`` with the new token
    written at each row's ``prev_length % S_buf``) into the pool through the
    gather table. Rows write disjoint private slots (scratch for stale rows),
    so the batched scatter is conflict-free."""
    buf = gather_idx.shape[1]
    start = (prev_length % buf).astype(jnp.int32)              # (B,)
    k_tok = _token_at(new_k, start)
    v_tok = _token_at(new_v, start)
    phys = jnp.take_along_axis(gather_idx, start[:, None], axis=1)[:, 0]
    return pool_k.at[:, phys].set(k_tok), pool_v.at[:, phys].set(v_tok)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def scatter_decode_token_quant(pool_k, pool_v, k_scale, v_scale, gather_idx,
                               prev_length, new_k, new_v):
    """Quantized twin of ``scatter_decode_token``: encode each row's new
    token per-(layer, head) vector and store int8 values + f16 scales."""
    buf = gather_idx.shape[1]
    start = (prev_length % buf).astype(jnp.int32)
    k_tok, k_sc = quantize_kv(_token_at(new_k, start))         # (L,B,KV,hd)
    v_tok, v_sc = quantize_kv(_token_at(new_v, start))
    phys = jnp.take_along_axis(gather_idx, start[:, None], axis=1)[:, 0]
    return (pool_k.at[:, phys].set(k_tok),
            pool_v.at[:, phys].set(v_tok),
            k_scale.at[:, phys].set(k_sc[..., 0].astype(k_scale.dtype)),
            v_scale.at[:, phys].set(v_sc[..., 0].astype(v_scale.dtype)))


def _range_vals(k_row, v_row, start, n):
    vals_k = jax.lax.dynamic_slice_in_dim(k_row[:, 0], start, n, axis=1)
    vals_v = jax.lax.dynamic_slice_in_dim(v_row[:, 0], start, n, axis=1)
    return vals_k, vals_v


@functools.partial(jax.jit, donate_argnums=(0, 1))
def scatter_row_range(pool_k, pool_v, phys_idx, k_row, v_row, start):
    """Persist a batch=1 sub-prefill's new K/V: the ``len(phys_idx)`` tokens
    written at dense slots ``[start, start + n)`` of ``k_row/v_row
    (L, 1, S_buf, KV, hd)`` go to pool slots ``phys_idx``."""
    vals_k, vals_v = _range_vals(k_row, v_row, start, phys_idx.shape[0])
    return (pool_k.at[:, phys_idx].set(vals_k),
            pool_v.at[:, phys_idx].set(vals_v))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def scatter_row_range_quant(pool_k, pool_v, k_scale, v_scale, phys_idx,
                            k_row, v_row, start):
    """Quantized twin of ``scatter_row_range``: per-vector encode the prompt
    range on its way into the private tail blocks."""
    vals_k, vals_v = _range_vals(k_row, v_row, start, phys_idx.shape[0])
    qk, sk = quantize_kv(vals_k)
    qv, sv = quantize_kv(vals_v)
    return (pool_k.at[:, phys_idx].set(qk),
            pool_v.at[:, phys_idx].set(qv),
            k_scale.at[:, phys_idx].set(sk[..., 0].astype(k_scale.dtype)),
            v_scale.at[:, phys_idx].set(sv[..., 0].astype(v_scale.dtype)))
