"""Page-table serving runtime: per-row gather tables over the block pool.

``PagedRowCache`` replaces the dense per-slot ``RowAttnCache`` of the
continuous scheduler with page-table indirection: each decode slot carries a
*gather table* ``gather_idx (B, S_buf) int32`` mapping the row's dense
(logical) slot ``s`` to a flat pool slot. Slots ``[0, n_doc)`` map into the
shared, ref-counted chunk pages (one HBM copy per chunk, pool-wide); slots
``[n_doc, ...)`` map into the row's private copy-on-write tail blocks where
its prompt and generated tokens land.

The decode step is gather → step → scatter:

1. ``gather_rows`` materializes the dense ``RowAttnCache`` *view* of the
   page table (a device temporary; persistent HBM holds one copy per chunk).
   Because the gather compacts each row's valid tokens in retrieval order,
   the view is value-identical to what the row-slotted path would hold —
   the engine then runs the **same jitted ``decode_step_rows`` executable**
   on it, which is what makes paged answers bit-identical to the
   ``RowAttnCache`` path by construction.
2. ``scatter_decode_token`` writes the step's new K/V (one token per row,
   at each row's ``length % S_buf`` dense slot) back through the gather
   table into that row's private tail block. Active rows always land in
   their own tail; retired rows are remapped to a per-slot scratch block
   (``scratch_row``) so their dummy decode steps can never touch pages a
   live request shares.

Sharing chunk pages requires chunk K content to be position-independent,
i.e. the paper-faithful restarted-positions mode (``rerotate=False``); the
engine gates paged mode on it.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import RowAttnCache
from repro.paged.pool import PagedKvPool


@dataclass
class RowPages:
    """Host-side page-table handle for one decode slot."""
    chunk_refs: List[str] = field(default_factory=list)  # one entry per ref
    private_blocks: List[int] = field(default_factory=list)
    n_doc: int = 0
    tail_slots: Optional[np.ndarray] = None  # pool slots of the private tail


class PagedRowCache:
    """Page-table decode state for ``max_slots`` rows over one shared pool.

    Device state mirrors ``RowAttnCache`` exactly (``slot_pos (B, S_buf)``,
    ``length (B,)``) plus the gather table; KV bytes live in ``pool.k/v``
    only. Host state tracks each slot's page handle for release.
    """

    def __init__(self, pool: PagedKvPool, max_slots: int, buf_size: int):
        self.pool = pool
        self.max_slots = max_slots
        self.buf_size = buf_size
        self.rows: List[RowPages] = [RowPages() for _ in range(max_slots)]
        # one permanent scratch block, shared by every slot: the write
        # target for dummy decode steps into stale (retired) rows. Stale
        # rows racing on one slot is fine — the values are garbage either
        # way and are masked by each row's slot_pos; what matters is that
        # stale writes can never land in pages a live request uses.
        self._scratch = pool.alloc_private(1)[0]
        gi = np.stack([self.scratch_row(s) for s in range(max_slots)])
        self.gather_idx = jnp.asarray(gi)
        self.slot_pos = jnp.full((max_slots, buf_size), -1, jnp.int32)
        self.length = jnp.zeros((max_slots,), jnp.int32)

    def scratch_row(self, slot: int) -> np.ndarray:
        """Gather row mapping every dense slot into the shared scratch block
        (cyclic): reads see masked garbage, writes land in scratch."""
        base = self._scratch * self.pool.block_size
        return (base + np.arange(self.buf_size) % self.pool.block_size
                ).astype(np.int32)

    # -- admit / retire ----------------------------------------------------------
    def install_row(self, slot: int, handle: RowPages,
                    gather_row: np.ndarray) -> None:
        self.rows[slot] = handle
        self.gather_idx = self.gather_idx.at[slot].set(
            jnp.asarray(gather_row))

    def set_row_state(self, slot: int, slot_pos_row, length_row) -> None:
        """Mirror ``insert_cache_row`` for the slot's position state."""
        self.slot_pos = self.slot_pos.at[slot].set(slot_pos_row)
        self.length = self.length.at[slot].set(length_row)

    def release_row(self, slot: int) -> None:
        """Retire a slot: decref shared chunk pages (pages another request
        holds stay exactly where they are), free the private tail, and remap
        the slot's writes to scratch. Position state stays stale (masked) —
        same lifecycle as the dense row-slotted path."""
        handle = self.rows[slot]
        for cid in handle.chunk_refs:
            self.pool.release(cid)
        self.pool.free_private(handle.private_blocks)
        self.rows[slot] = RowPages()
        self.gather_idx = self.gather_idx.at[slot].set(
            jnp.asarray(self.scratch_row(slot)))

    # -- dense views ---------------------------------------------------------------
    def dense_view(self) -> RowAttnCache:
        k, v = gather_rows(self.pool.k, self.pool.v, self.gather_idx)
        return RowAttnCache(k=k, v=v, slot_pos=self.slot_pos,
                            length=self.length)

    def dense_row_view(self, slot: int) -> RowAttnCache:
        k, v = gather_rows(self.pool.k, self.pool.v,
                           self.gather_idx[slot][None])
        return RowAttnCache(k=k, v=v, slot_pos=self.slot_pos[slot][None],
                            length=self.length[slot][None])


# ---------------------------------------------------------------------------
# jitted gather / scatter
# ---------------------------------------------------------------------------

@jax.jit
def gather_rows(pool_k, pool_v, gather_idx):
    """(L, N_slots, KV, hd) pool + (B, S_buf) table -> (L, B, S_buf, KV, hd)
    dense view. Table entries are taken literally (callers map padding slots
    to private/scratch blocks, whose values are masked by slot_pos)."""
    b, s = gather_idx.shape
    idx = gather_idx.reshape(-1)
    k = jnp.take(pool_k, idx, axis=1)
    v = jnp.take(pool_v, idx, axis=1)
    shape = (pool_k.shape[0], b, s) + pool_k.shape[2:]
    return k.reshape(shape), v.reshape(shape)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def scatter_decode_token(pool_k, pool_v, gather_idx, prev_length,
                         new_k, new_v):
    """Persist one decode step's new K/V (``new_k/v (L, B, S_buf, KV, hd)``,
    the dense buffers returned by ``decode_step_rows`` with the new token
    written at each row's ``prev_length % S_buf``) into the pool through the
    gather table. Rows write disjoint private slots (scratch for stale rows),
    so the batched scatter is conflict-free."""
    buf = gather_idx.shape[1]
    start = (prev_length % buf).astype(jnp.int32)              # (B,)
    k_tok = jnp.take_along_axis(
        new_k, start[None, :, None, None, None], axis=2)[:, :, 0]
    v_tok = jnp.take_along_axis(
        new_v, start[None, :, None, None, None], axis=2)[:, :, 0]
    phys = jnp.take_along_axis(gather_idx, start[:, None], axis=1)[:, 0]
    return pool_k.at[:, phys].set(k_tok), pool_v.at[:, phys].set(v_tok)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def scatter_row_range(pool_k, pool_v, phys_idx, k_row, v_row, start):
    """Persist a batch=1 sub-prefill's new K/V: the ``len(phys_idx)`` tokens
    written at dense slots ``[start, start + n)`` of ``k_row/v_row
    (L, 1, S_buf, KV, hd)`` go to pool slots ``phys_idx``."""
    n = phys_idx.shape[0]
    vals_k = jax.lax.dynamic_slice_in_dim(k_row[:, 0], start, n, axis=1)
    vals_v = jax.lax.dynamic_slice_in_dim(v_row[:, 0], start, n, axis=1)
    return (pool_k.at[:, phys_idx].set(vals_k),
            pool_v.at[:, phys_idx].set(vals_v))
