"""Document chunking for the MatKV ingest pipeline (paper §IV).

Documents are token sequences; chunks are fixed-size windows (default 1,024
tokens, the paper's setting). Chunk ids are content hashes, so identical chunks
dedupe naturally across documents and the id doubles as the flash-store key.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterable, List, Sequence

import numpy as np

DEFAULT_CHUNK_TOKENS = 1024


@dataclass(frozen=True)
class Chunk:
    chunk_id: str
    tokens: np.ndarray  # (len,) int32
    doc_id: str
    index: int  # position of this chunk within its document

    def __len__(self) -> int:
        return int(self.tokens.shape[0])


def chunk_id_for(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.asarray(tokens, np.int32).tobytes()).hexdigest()[:16]


def chunk_document(doc_id: str, tokens: Sequence[int],
                   chunk_tokens: int = DEFAULT_CHUNK_TOKENS,
                   drop_ragged_tail: bool = False) -> List[Chunk]:
    toks = np.asarray(tokens, np.int32)
    chunks = []
    for i in range(0, len(toks), chunk_tokens):
        part = toks[i:i + chunk_tokens]
        if drop_ragged_tail and len(part) < chunk_tokens:
            break
        chunks.append(Chunk(chunk_id=chunk_id_for(part), tokens=part,
                            doc_id=doc_id, index=i // chunk_tokens))
    return chunks


def chunk_corpus(docs: Iterable[tuple], chunk_tokens: int = DEFAULT_CHUNK_TOKENS
                 ) -> List[Chunk]:
    """docs: iterable of (doc_id, tokens). Returns all chunks (deduped by id)."""
    seen, out = set(), []
    for doc_id, tokens in docs:
        for c in chunk_document(doc_id, tokens, chunk_tokens):
            if c.chunk_id not in seen:
                seen.add(c.chunk_id)
                out.append(c)
    return out
