"""Chunk-KV materialization — the MatKV write path (paper §III-B, Fig. 3a).

``Materializer`` runs a chunk through the model's prefill once (at ingest
time), serializes the per-layer KV stacks (or recurrent states / cross-KV,
per family) and persists them in the flash store keyed by chunk_id. Prefill is
jitted per padded length bucket so ragged chunks don't trigger recompiles.

Artifacts may be stored quantized (int8 + f16 scales, DESIGN.md §9), halving
both the flash footprint and the load bytes.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunking import Chunk
from repro.core.quantize import dequantize_kv, quantize_kv
from repro.kvstore.serialization import deserialize, serialize


def _bucket(n: int) -> int:
    """Pad ragged chunk lengths to the next power-of-two bucket (min 16)."""
    b = 16
    while b < n:
        b *= 2
    return b


class Materializer:
    def __init__(self, model, params, store, quantized: bool = False):
        self.model = model
        self.params = params
        self.store = store
        self.quantized = quantized
        self.cfg = model.cfg
        self._jitted = {}

    # -- write path ------------------------------------------------------------
    def _prefill_fn(self, padded_len: int):
        if padded_len not in self._jitted:
            def fn(params, tokens):
                _, artifact = self.model.prefill(params, {"tokens": tokens})
                return artifact
            self._jitted[padded_len] = jax.jit(fn)
        return self._jitted[padded_len]

    def compute_artifact(self, tokens: np.ndarray):
        """tokens (S,) -> family-specific artifact, trimmed to true length."""
        s = int(tokens.shape[0])
        pad = _bucket(s)
        padded = np.zeros((1, pad), np.int32)
        padded[0, :s] = tokens
        if self.model.is_encdec:
            # audio chunks: tokens stand in for frame ids; the stub frontend
            # provides embeddings directly (see serving engine / input_specs)
            raise ValueError("use compute_audio_artifact for enc-dec models")
        artifact = self._prefill_fn(pad)(self.params, jnp.asarray(padded))
        return self._trim(artifact, s)

    def compute_audio_artifact(self, frames: np.ndarray):
        """frames (T, D) stub embeddings -> cross-KV artifact (enc-dec)."""
        fn = jax.jit(lambda p, f: self.model.prefill(p, {"frontend": f})[1])
        return fn(self.params, jnp.asarray(frames)[None])

    def _trim(self, artifact, s: int):
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            k, v = artifact
            return (k[:, :, :s], v[:, :, :s])
        if fam == "ssm":
            # state after padded zeros is NOT the state after s tokens if pad
            # tokens were appended — we pad with zeros *after* and mask is not
            # applied, so recompute on exact length instead for ssm/hybrid.
            return artifact
        if fam == "hybrid":
            (k, v), rec = artifact
            return ((k[:, :, :s], v[:, :, :s]), rec)
        return artifact

    def _prefill_exact(self, tokens: np.ndarray):
        """Recurrent families: run at exact length (padding would corrupt the
        final state). jit per distinct length (chunk sizes are uniform)."""
        key = ("exact", int(tokens.shape[0]))
        if key not in self._jitted:
            def fn(params, toks):
                _, artifact = self.model.prefill(params, {"tokens": toks})
                return artifact
            self._jitted[key] = jax.jit(fn)
        return self._jitted[key](self.params, jnp.asarray(tokens)[None])

    def artifact_tensors(self, artifact) -> Dict[str, np.ndarray]:
        """Flatten an artifact to named tensors (batch dim squeezed)."""
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            k, v = artifact
            out = {"k": k[:, 0], "v": v[:, 0]}
        elif fam == "ssm":
            conv, h = artifact
            out = {"conv": conv[:, 0], "h": h[:, 0]}
        elif fam == "hybrid":
            (k, v), (conv, h) = artifact
            out = {"k": k[:, 0], "v": v[:, 0], "conv": conv[:, 0], "h": h[:, 0]}
        else:  # encdec
            ck, cv = artifact
            out = {"cross_k": ck[:, 0], "cross_v": cv[:, 0]}
        out = {n: np.asarray(a) for n, a in out.items()}
        if self.quantized:
            q = {}
            for n, a in out.items():
                if n in ("k", "v", "cross_k", "cross_v"):
                    qv, sc = quantize_kv(jnp.asarray(a))
                    q[n + ".q8"] = np.asarray(qv)
                    q[n + ".scale"] = np.asarray(sc)
                else:
                    q[n] = a
            out = q
        return out

    def ingest(self, chunk: Chunk) -> int:
        """Materialize one chunk; returns stored payload size in bytes."""
        if self.cfg.family in ("ssm", "hybrid"):
            artifact = self._prefill_exact(chunk.tokens)
        else:
            artifact = self.compute_artifact(chunk.tokens)
        tensors = self.artifact_tensors(artifact)
        meta = {"arch": self.cfg.name, "family": self.cfg.family,
                "n_tokens": len(chunk), "chunk_id": chunk.chunk_id,
                "doc_id": chunk.doc_id, "quantized": self.quantized}
        payload = serialize(tensors, meta)
        self.store.put(chunk.chunk_id, payload)
        return len(payload)

    def ingest_corpus(self, chunks: Sequence[Chunk]) -> int:
        return sum(self.ingest(c) for c in chunks)


# -- read path ----------------------------------------------------------------

def load_artifact(cfg, payload: bytes, dtype=None):
    """bytes -> (family artifact with batch dim restored, meta)."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    tensors, meta = deserialize(payload)

    def deq(name):
        if name + ".q8" in tensors:
            return dequantize_kv(jnp.asarray(tensors[name + ".q8"]),
                                 jnp.asarray(tensors[name + ".scale"]), dtype)
        return jnp.asarray(tensors[name]).astype(dtype)

    fam = meta["family"]
    if fam in ("dense", "vlm", "moe"):
        art = (deq("k")[:, None], deq("v")[:, None])
    elif fam == "ssm":
        art = (jnp.asarray(tensors["conv"])[:, None],
               jnp.asarray(tensors["h"])[:, None].astype(jnp.float32))
    elif fam == "hybrid":
        art = ((deq("k")[:, None], deq("v")[:, None]),
               (jnp.asarray(tensors["conv"])[:, None],
                jnp.asarray(tensors["h"])[:, None].astype(jnp.float32)))
    else:  # encdec / audio
        art = (deq("cross_k")[:, None], deq("cross_v")[:, None])
    return art, meta
