"""Chunk-KV materialization — the MatKV write path (paper §III-B, Fig. 3a).

``Materializer`` runs a chunk through the model's prefill once (at ingest
time), serializes the per-layer KV stacks (or recurrent states / cross-KV,
per family) and persists them in the flash store keyed by chunk_id. Prefill is
jitted per padded length bucket so ragged chunks don't trigger recompiles.

The storage width of an artifact is owned by a ``KvCodec`` (DESIGN.md §11):
the materializer encodes KV tensors with it, the serialized header carries
its id, and the read path either widens on decode (``load_artifact``, the
dense compose path) or hands the encoded tensors straight through
(``load_artifact_encoded``, the paged-pool path — int8 stays int8 from flash
to the decode step).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunking import Chunk
from repro.core.quantize import (EncodedKV, KvCodec, codec_for_meta,
                                 get_codec)
from repro.kvstore.serialization import deserialize, serialize
from repro.obs import NULL_TRACER

# logical tensor names the codec applies to; recurrent states (conv/h) stay
# at full width — they are O(1) per chunk, not per-token
KV_TENSORS = ("k", "v", "cross_k", "cross_v")


def _bucket(n: int) -> int:
    """Pad ragged chunk lengths to the next power-of-two bucket (min 16)."""
    b = 16
    while b < n:
        b *= 2
    return b


class Materializer:
    def __init__(self, model, params, store,
                 codec: Union[str, KvCodec, None] = None, tracer=None):
        self.model = model
        self.params = params
        self.store = store
        self.codec = get_codec(codec)
        self.tracer = tracer or NULL_TRACER
        self.cfg = model.cfg
        self._jitted = {}

    # -- write path ------------------------------------------------------------
    def _prefill_fn(self, padded_len: int):
        if padded_len not in self._jitted:
            def fn(params, tokens):
                _, artifact = self.model.prefill(params, {"tokens": tokens})
                return artifact
            self._jitted[padded_len] = jax.jit(fn)
        return self._jitted[padded_len]

    def compute_artifact(self, tokens: np.ndarray):
        """tokens (S,) -> family-specific artifact, trimmed to true length."""
        s = int(tokens.shape[0])
        pad = _bucket(s)
        padded = np.zeros((1, pad), np.int32)
        padded[0, :s] = tokens
        if self.model.is_encdec:
            # audio chunks: tokens stand in for frame ids; the stub frontend
            # provides embeddings directly (see serving engine / input_specs)
            raise ValueError("use compute_audio_artifact for enc-dec models")
        artifact = self._prefill_fn(pad)(self.params, jnp.asarray(padded))
        return self._trim(artifact, s)

    def compute_audio_artifact(self, frames: np.ndarray):
        """frames (T, D) stub embeddings -> cross-KV artifact (enc-dec)."""
        fn = jax.jit(lambda p, f: self.model.prefill(p, {"frontend": f})[1])
        return fn(self.params, jnp.asarray(frames)[None])

    def _trim(self, artifact, s: int):
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            k, v = artifact
            return (k[:, :, :s], v[:, :, :s])
        if fam == "ssm":
            # state after padded zeros is NOT the state after s tokens if pad
            # tokens were appended — we pad with zeros *after* and mask is not
            # applied, so recompute on exact length instead for ssm/hybrid.
            return artifact
        if fam == "hybrid":
            (k, v), rec = artifact
            return ((k[:, :, :s], v[:, :, :s]), rec)
        return artifact

    def _prefill_exact(self, tokens: np.ndarray):
        """Recurrent families: run at exact length (padding would corrupt the
        final state). jit per distinct length (chunk sizes are uniform)."""
        key = ("exact", int(tokens.shape[0]))
        if key not in self._jitted:
            def fn(params, toks):
                _, artifact = self.model.prefill(params, {"tokens": toks})
                return artifact
            self._jitted[key] = jax.jit(fn)
        return self._jitted[key](self.params, jnp.asarray(tokens)[None])

    def artifact_tensors(self, artifact) -> Dict[str, np.ndarray]:
        """Flatten an artifact to named tensors (batch dim squeezed), with KV
        tensors in the codec's wire form."""
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            k, v = artifact
            out = {"k": k[:, 0], "v": v[:, 0]}
        elif fam == "ssm":
            conv, h = artifact
            out = {"conv": conv[:, 0], "h": h[:, 0]}
        elif fam == "hybrid":
            (k, v), (conv, h) = artifact
            out = {"k": k[:, 0], "v": v[:, 0], "conv": conv[:, 0], "h": h[:, 0]}
        else:  # encdec
            ck, cv = artifact
            out = {"cross_k": ck[:, 0], "cross_v": cv[:, 0]}
        encoded = {}
        for n, a in out.items():
            if n in KV_TENSORS:
                encoded.update(self.codec.encode_named(n, a))
            else:
                encoded[n] = np.asarray(a)
        return encoded

    def ingest(self, chunk: Chunk,
               extra_meta: Optional[Dict] = None) -> int:
        """Materialize one chunk; returns stored payload size in bytes.
        ``extra_meta`` entries (e.g. the role split's ``generation`` tag,
        DESIGN.md §14) ride along in the artifact header — readers that
        don't know a key ignore it."""
        with self.tracer.span("chunk_prefill", chunk=chunk.chunk_id,
                              tokens=len(chunk)):
            if self.cfg.family in ("ssm", "hybrid"):
                artifact = self._prefill_exact(chunk.tokens)
            else:
                artifact = self.compute_artifact(chunk.tokens)
            tensors = self.artifact_tensors(artifact)
        meta = {"arch": self.cfg.name, "family": self.cfg.family,
                "n_tokens": len(chunk), "chunk_id": chunk.chunk_id,
                "doc_id": chunk.doc_id, "codec": self.codec.codec_id}
        if extra_meta:
            meta.update(extra_meta)
        payload = serialize(tensors, meta)
        with self.tracer.span("durable_put", chunk=chunk.chunk_id,
                              bytes=len(payload)):
            self.store.put(chunk.chunk_id, payload)
        return len(payload)

    def ingest_corpus(self, chunks: Sequence[Chunk]) -> int:
        return sum(self.ingest(c) for c in chunks)


# -- read path ----------------------------------------------------------------

def load_artifact(cfg, payload: bytes, dtype=None):
    """bytes -> (family artifact with batch dim restored, meta).

    The *widening* read path: KV tensors are decoded to ``dtype`` via the
    artifact's codec — what the dense compose paths consume. The paged pool
    uses ``load_artifact_encoded`` instead and never widens.
    """
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    tensors, meta = deserialize(payload)
    codec = codec_for_meta(meta)

    def deq(name):
        return codec.decode_named(tensors, name, dtype)

    fam = meta["family"]
    if fam in ("dense", "vlm", "moe"):
        art = (deq("k")[:, None], deq("v")[:, None])
    elif fam == "ssm":
        art = (jnp.asarray(tensors["conv"])[:, None],
               jnp.asarray(tensors["h"])[:, None].astype(jnp.float32))
    elif fam == "hybrid":
        art = ((deq("k")[:, None], deq("v")[:, None]),
               (jnp.asarray(tensors["conv"])[:, None],
                jnp.asarray(tensors["h"])[:, None].astype(jnp.float32)))
    else:  # encdec / audio
        art = (deq("cross_k")[:, None], deq("cross_v")[:, None])
    return art, meta


def load_artifact_encoded(cfg, payload: bytes) -> Tuple[EncodedKV, dict]:
    """bytes -> (EncodedKV in storage dtype, meta) — no widening.

    Attention-KV families only (the paged pool's unit of storage); the
    tensors keep the artifact codec's representation, so an int8 artifact
    flows from flash into int8 pool pages without ever becoming bf16.
    """
    tensors, meta = deserialize(payload)
    codec = codec_for_meta(meta)
    fam = meta["family"]
    if fam in ("dense", "vlm", "moe"):
        kn, vn = "k", "v"
    elif fam in ("encdec", "audio"):
        kn, vn = "cross_k", "cross_v"
    else:
        raise ValueError(f"load_artifact_encoded: family {fam!r} has no "
                         f"attention-KV artifact")
    if codec.scale_dtype is None:
        k, v = tensors[kn], tensors[vn]
        k_scale = v_scale = None
    else:
        k, v = tensors[kn + ".q8"], tensors[vn + ".q8"]
        k_scale, v_scale = tensors[kn + ".scale"], tensors[vn + ".scale"]
    return EncodedKV(codec=codec, k=k, v=v, k_scale=k_scale, v_scale=v_scale,
                     n_tokens=int(meta["n_tokens"])), meta
