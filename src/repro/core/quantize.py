"""KV storage codecs: the dtype of a KV artifact, end to end (DESIGN.md §11).

MatKV's economics scale linearly with flash bytes, so the *stored* width of a
KV artifact is a first-class system property, not a leaf feature. A
``KvCodec`` names one storage representation and owns every conversion in and
out of it:

* ``Bf16Codec`` — passthrough: artifacts are stored at the model's activation
  width (the paper's baseline).
* ``Int8Codec`` — symmetric per-(layer, token, head) int8 over the head_dim
  axis with f16 scales: ~0.52x the bytes of bf16, which halves flash
  footprint, load bytes and PCIe traffic, and doubles the Eq.-1 break-even
  interval.

The codec is threaded through the whole KV path: ``Materializer`` encodes
with it at ingest, the serialized header carries its id, the host cache tiers
and loaders account *encoded* bytes, ``PagedKvPool`` stores blocks in the
codec's layout (so a fixed HBM budget holds ~2x the chunks under int8), and
the decode step widens on-chip — either in the fused Pallas kernel
(``kernels.paged_decode_quant``) or in the jitted gather/dequant op
(``paged.runtime.gather_rows_quant``). ``quantize_kv`` / ``dequantize_kv``
remain the reference scalar math both sides must match bit-for-bit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., hd) float -> (int8 values (..., hd), f16 scales (..., 1))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def quantization_error(x: jnp.ndarray) -> float:
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    denom = float(jnp.sqrt(jnp.mean(x.astype(jnp.float32) ** 2))) + 1e-12
    return float(jnp.sqrt(jnp.mean((back - x.astype(jnp.float32)) ** 2))) / denom


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EncodedKV:
    """One chunk's attention-KV artifact in its storage representation:
    ``k`` / ``v`` (L, S, KV, hd) in the codec's storage dtype, plus the
    per-vector scale tensors (L, S, KV, 1) for quantizing codecs (None for
    passthrough). This is what flows from flash into the paged pool without
    ever being widened."""
    codec: "KvCodec"
    k: Any
    v: Any
    k_scale: Optional[Any] = None
    v_scale: Optional[Any] = None
    n_tokens: int = 0


class KvCodec:
    """One KV storage representation. Subclasses define the value/scale
    tensors, the wire names, and the byte accounting; everything else in the
    system dispatches through this interface instead of a boolean flag."""

    codec_id: str = "?"
    storage_dtype = None          # None -> the model's activation dtype
    scale_dtype = None            # None -> no scale tensor

    # -- array form (pool / kernels) ---------------------------------------
    def encode(self, x) -> Tuple[Any, Optional[Any]]:
        """float (..., hd) -> (stored values, per-vector scales or None)."""
        raise NotImplementedError

    def decode(self, values, scales, dtype=jnp.bfloat16):
        """Stored (values, scales) -> float (..., hd) in ``dtype``."""
        raise NotImplementedError

    # -- wire form (serialization) -----------------------------------------
    def encode_named(self, name: str, arr) -> Dict[str, np.ndarray]:
        """One logical tensor -> the flat serialized tensors carrying it."""
        raise NotImplementedError

    def decode_named(self, tensors: Dict[str, Any], name: str,
                     dtype=jnp.bfloat16):
        raise NotImplementedError

    def carries(self, tensors: Dict[str, Any], name: str) -> bool:
        """Whether ``tensors`` holds ``name`` in this codec's wire form."""
        raise NotImplementedError

    # -- accounting --------------------------------------------------------
    def bytes_per_vector(self, head_dim: int, act_itemsize: int = 2) -> int:
        """Stored bytes of one (token, head) KV vector."""
        raise NotImplementedError

    def kv_bytes_per_token(self, cfg, act_itemsize: int = 2) -> int:
        """Encoded flash bytes per token — the codec-aware counterpart of
        ``ModelConfig.kv_bytes_per_token`` (the quantity Eq. 1 prices)."""
        widened = cfg.kv_bytes_per_token(act_itemsize)
        if widened == 0:
            return 0
        per_head = cfg.head_dim * act_itemsize
        n_vectors = widened // per_head        # 2 * n_attn * num_kv_heads
        return n_vectors * self.bytes_per_vector(cfg.head_dim, act_itemsize)

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class Bf16Codec(KvCodec):
    """Passthrough: store at activation width (the paper's baseline)."""

    codec_id = "bf16"

    def encode(self, x):
        return x, None

    def decode(self, values, scales, dtype=jnp.bfloat16):
        return jnp.asarray(values).astype(dtype)

    def encode_named(self, name, arr):
        return {name: np.asarray(arr)}

    def decode_named(self, tensors, name, dtype=jnp.bfloat16):
        return jnp.asarray(tensors[name]).astype(dtype)

    def carries(self, tensors, name):
        return name in tensors

    def bytes_per_vector(self, head_dim, act_itemsize=2):
        return head_dim * act_itemsize


class Int8Codec(KvCodec):
    """Symmetric per-(layer, token, head) int8 with f16 scales."""

    codec_id = "int8"
    storage_dtype = jnp.int8
    scale_dtype = jnp.float16

    def encode(self, x):
        return quantize_kv(jnp.asarray(x))

    def decode(self, values, scales, dtype=jnp.bfloat16):
        return dequantize_kv(jnp.asarray(values), jnp.asarray(scales), dtype)

    def encode_named(self, name, arr):
        q, s = quantize_kv(jnp.asarray(arr))
        return {name + ".q8": np.asarray(q), name + ".scale": np.asarray(s)}

    def decode_named(self, tensors, name, dtype=jnp.bfloat16):
        return dequantize_kv(jnp.asarray(tensors[name + ".q8"]),
                             jnp.asarray(tensors[name + ".scale"]), dtype)

    def carries(self, tensors, name):
        return name + ".q8" in tensors

    def bytes_per_vector(self, head_dim, act_itemsize=2):
        return head_dim + np.dtype(np.float16).itemsize   # int8 values + scale


_CODECS: Dict[str, KvCodec] = {c.codec_id: c for c in (Bf16Codec(), Int8Codec())}


def get_codec(codec: Union[str, KvCodec, None]) -> KvCodec:
    """Resolve a codec id / instance / None (-> bf16 passthrough)."""
    if codec is None:
        return _CODECS["bf16"]
    if isinstance(codec, KvCodec):
        return codec
    try:
        return _CODECS[codec]
    except KeyError:
        raise ValueError(f"unknown KV codec {codec!r}; "
                         f"known: {sorted(_CODECS)}") from None


def codec_for_meta(meta: Dict[str, Any]) -> KvCodec:
    """The codec an artifact was written with. Artifacts from before the
    codec layer carried a ``quantized`` bool instead of a codec id."""
    cid = meta.get("codec")
    if cid is None:
        cid = "int8" if meta.get("quantized") else "bf16"
    return get_codec(cid)
