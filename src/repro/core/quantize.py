"""Int8 KV quantization for flash storage (beyond-paper extension, DESIGN.md §9).

Symmetric per-(layer, token, head) quantization over the head_dim axis. Halves
the bytes MatKV stores and loads versus bf16 — which doubles the ten-day-rule
break-even interval and halves load latency. The Pallas kernel in
``repro.kernels.kv_dequant`` performs the on-load dequantization on-chip; the
functions here are the reference implementation and the host-side quantizer.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., hd) float -> (int8 values (..., hd), f16 scales (..., 1))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def quantization_error(x: jnp.ndarray) -> float:
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    denom = float(jnp.sqrt(jnp.mean(x.astype(jnp.float32) ** 2))) + 1e-12
    return float(jnp.sqrt(jnp.mean((back - x.astype(jnp.float32)) ** 2))) / denom
