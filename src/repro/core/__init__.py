# The paper's primary contribution: MatKV — materialize chunk KV caches on
# flash at ingest; load + compose + sub-prefill at query time instead of
# recomputing the prefill.
from repro.core.chunking import Chunk, chunk_corpus, chunk_document
from repro.core.compose import (compose_attn_cache, compose_encdec_cache,
                                compose_hybrid_cache, compose_ssm_cache)
from repro.core.economics import (H100, PM9A3, RAID0_9100_PRO_X4, RTX4090,
                                  SAMSUNG_9100_PRO, break_even_interval_days)
from repro.core.materialize import (Materializer, load_artifact,
                                    load_artifact_encoded)
from repro.core.quantize import (Bf16Codec, EncodedKV, Int8Codec, KvCodec,
                                 codec_for_meta, dequantize_kv, get_codec,
                                 quantize_kv)

__all__ = [
    "Chunk", "chunk_corpus", "chunk_document",
    "compose_attn_cache", "compose_encdec_cache", "compose_hybrid_cache",
    "compose_ssm_cache", "Materializer", "load_artifact",
    "load_artifact_encoded",
    "KvCodec", "Bf16Codec", "Int8Codec", "EncodedKV", "get_codec",
    "codec_for_meta",
    "quantize_kv", "dequantize_kv", "break_even_interval_days",
    "H100", "RTX4090", "SAMSUNG_9100_PRO", "RAID0_9100_PRO_X4", "PM9A3",
]
