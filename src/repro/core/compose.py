"""KV composition — the MatKV read path (paper §III-B).

Loaded per-chunk artifacts are concatenated *in retrieval order* in front of the
user query. Chunks were prefilled independently at positions [0, L_i), so:

* paper-faithful mode (``rerotate=False``): cached keys keep their restarted
  per-chunk RoPE positions (exactly what the paper's prototype does with
  past_kv_caches);
* re-rotated mode (``rerotate=True``, beyond-paper): each chunk's keys are
  rotated by its global start offset — O(S·hd) elementwise, no projections —
  restoring globally consistent positions.

Either way, *attention-order* slot positions are global (0..total-1) so the
query attends causally to every document token, and documents never attend to
each other (their KVs are already frozen) — the paper's key accuracy insight.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.streaming_prefix import carry_init, carry_update
from repro.models.cache import (AttnCache, EncDecCache, HybridCache,
                                RowAttnCache, SSMCache, init_attn_cache)
from repro.models.rope import rerotate_keys


def compose_attn_cache(cfg, artifacts: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
                       buf_size: int, rerotate: bool = False,
                       dtype=None) -> AttnCache:
    """artifacts: [(k, v)] with k/v (L, B, S_i, KV, hd) -> AttnCache.

    The composed prefix occupies slots [0, total); if total exceeds ``buf_size``
    (sliding-window archs) only the last ``buf_size`` tokens are kept, which is
    exactly what a window attention would ever read.
    """
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    ks, vs, offset = [], [], 0
    for (k, v) in artifacts:
        if rerotate and cfg.use_rope and offset:
            # k is (L, B, S, KV, hd); rerotate_keys expects (B, S, KV, hd)
            k = jax.vmap(lambda kl: rerotate_keys(kl, offset, cfg.rope_theta))(k)
        ks.append(k.astype(dtype))
        vs.append(v.astype(dtype))
        offset += k.shape[2]
    k_all = jnp.concatenate(ks, axis=2)
    v_all = jnp.concatenate(vs, axis=2)
    total = k_all.shape[2]
    pos = jnp.arange(total, dtype=jnp.int32)
    if total > buf_size:
        k_all = k_all[:, :, -buf_size:]
        v_all = v_all[:, :, -buf_size:]
        pos = pos[-buf_size:]
    n_layers, batch = k_all.shape[0], k_all.shape[1]
    cache = init_attn_cache(cfg, batch, buf_size, n_layers=n_layers, dtype=dtype)
    buf = cache.buf_size
    pad = buf - k_all.shape[2]
    if pad:
        zeros = jnp.zeros(k_all.shape[:2] + (pad,) + k_all.shape[3:], dtype)
        k_all = jnp.concatenate([k_all, zeros], axis=2)
        v_all = jnp.concatenate([v_all, zeros], axis=2)
        pos = jnp.concatenate([pos, jnp.full((pad,), -1, jnp.int32)])
    return AttnCache(k=k_all, v=v_all, slot_pos=pos,
                     length=jnp.asarray(total, jnp.int32))


def compose_attn_cache_rows(cfg, row_artifacts, buf_size: int,
                            rerotate: bool = False, dtype=None
                            ) -> RowAttnCache:
    """Variable-geometry batch composition for continuous batching.

    ``row_artifacts``: one list of (k, v) chunk artifacts per batch row — rows
    may carry different chunk counts (``top_k``), different chunk lengths
    (short final chunks), or no chunks at all (query-only row after empty
    retrieval). Every row is composed exactly like ``compose_attn_cache``
    (retrieval-order concat, optional re-rotation), right-padded to
    ``buf_size`` with -1 slot positions, and stacked into one batched
    ``RowAttnCache`` with per-row lengths.
    """
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    n_layers = None
    for arts in row_artifacts:
        if arts:
            n_layers = arts[0][0].shape[0]
            break
    if n_layers is None:
        n_layers = cfg.num_layers
    kv_tail = (cfg.num_kv_heads, cfg.head_dim)

    row_ks, row_vs, row_pos, row_len = [], [], [], []
    for arts in row_artifacts:
        ks, vs, offset = [], [], 0
        for (k, v) in arts:
            if rerotate and cfg.use_rope and offset:
                k = jax.vmap(lambda kl, off=offset: rerotate_keys(
                    kl, off, cfg.rope_theta))(k)
            ks.append(k.astype(dtype))
            vs.append(v.astype(dtype))
            offset += k.shape[2]
        if ks:
            k_all = jnp.concatenate(ks, axis=2)
            v_all = jnp.concatenate(vs, axis=2)
        else:
            k_all = jnp.zeros((n_layers, 1, 0) + kv_tail, dtype)
            v_all = jnp.zeros((n_layers, 1, 0) + kv_tail, dtype)
        total = k_all.shape[2]
        if total > buf_size:
            k_all = k_all[:, :, -buf_size:]
            v_all = v_all[:, :, -buf_size:]
            pos = jnp.arange(total, dtype=jnp.int32)[-buf_size:]
        else:
            pos = jnp.arange(total, dtype=jnp.int32)
        pad = buf_size - k_all.shape[2]
        if pad:
            zeros = jnp.zeros(k_all.shape[:2] + (pad,) + k_all.shape[3:],
                              dtype)
            k_all = jnp.concatenate([k_all, zeros], axis=2)
            v_all = jnp.concatenate([v_all, zeros], axis=2)
            pos = jnp.concatenate([pos, jnp.full((pad,), -1, jnp.int32)])
        row_ks.append(k_all)
        row_vs.append(v_all)
        row_pos.append(pos)
        row_len.append(total)
    return RowAttnCache(
        k=jnp.concatenate(row_ks, axis=1),
        v=jnp.concatenate(row_vs, axis=1),
        slot_pos=jnp.stack(row_pos),
        length=jnp.asarray(row_len, jnp.int32))


@dataclass
class StreamingPrefix:
    """Streamed composition state for one row (streaming admission, §16).

    Holds the row's roped layer-0 prompt queries ``q0`` and the
    flash-attention (m, l, acc) carry over however much of the document
    prefix has landed. The scheduler folds blocks *in retrieval-token
    order* as the loader delivers them (``update``), then hands the carry
    to ``decode_step_rows_streamed`` for the finalize step — so the
    prompt-over-document attention work is already done by the time the
    last page lands, and the first token still matches the all-at-once
    composition (the carry restates ``_flash_fwd``'s exact online body).
    """
    q0: jnp.ndarray          # (1, Sq, H, hd) — layer-0 prompt queries, roped
    m: jnp.ndarray           # (1, KV, G, Sq, 1) f32 running max
    l: jnp.ndarray           # (1, KV, G, Sq, 1) f32 running denominator
    acc: jnp.ndarray         # (1, Sq, KV, G, hd) f32 weighted-V accumulator
    n_seen: int = 0          # document tokens folded so far
    bucket: int = 64         # pad widths to multiples of this (retrace bound)

    @classmethod
    def begin(cls, q0: jnp.ndarray, n_kv_heads: int,
              bucket: int = 64) -> "StreamingPrefix":
        b, sq, h, hd = q0.shape
        m, l, acc = carry_init(b, sq, h, n_kv_heads, hd)
        return cls(q0=q0, m=m, l=l, acc=acc, n_seen=0, bucket=max(1, bucket))

    def update(self, k_blk, v_blk) -> int:
        """Fold one decoded document block (k/v ``(n, KV, hd)`` or batched
        ``(1, n, KV, hd)``), padded to a bucket width so the jitted update
        retraces once per bucket rather than once per arrival width.
        Returns the new folded-token count."""
        k = jnp.asarray(k_blk).astype(self.q0.dtype)
        v = jnp.asarray(v_blk).astype(self.q0.dtype)
        if k.ndim == 3:
            k, v = k[None], v[None]
        n = k.shape[1]
        w = -(-n // self.bucket) * self.bucket
        if w != n:
            z = jnp.zeros((k.shape[0], w - n) + k.shape[2:], k.dtype)
            k = jnp.concatenate([k, z], axis=1)
            v = jnp.concatenate([v, z], axis=1)
        self.m, self.l, self.acc = carry_update(
            self.m, self.l, self.acc, self.q0, k, v, n)
        self.n_seen += int(n)
        return self.n_seen


def compose_ssm_cache(cfg, artifact, n_tokens: int) -> SSMCache:
    """Single-chunk prefix reuse for SSMs (DESIGN.md §4): the materialized final
    (conv, h) state of the chunk becomes the decode-time initial state."""
    conv, h = artifact
    return SSMCache(conv=conv, h=h.astype(jnp.float32),
                    length=jnp.asarray(n_tokens, jnp.int32))


def compose_hybrid_cache(cfg, artifact, n_tokens: int, buf_size: int,
                         dtype=None) -> HybridCache:
    """Single-chunk prefix reuse for hybrid archs: window KV for attention
    layers + final recurrent states. Multi-chunk composition is not sound for
    the recurrent path (see DESIGN.md §4) — the engine chains chunks instead."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    (k, v), (conv, h) = artifact
    buf = min(buf_size, cfg.sliding_window or buf_size)
    s = k.shape[2]
    keep = min(s, buf)
    pos = jnp.arange(s, dtype=jnp.int32)[-keep:]
    k = k[:, :, -keep:].astype(dtype)
    v = v[:, :, -keep:].astype(dtype)
    pad = buf - keep
    if pad:
        zeros = jnp.zeros(k.shape[:2] + (pad,) + k.shape[3:], dtype)
        k = jnp.concatenate([k, zeros], axis=2)
        v = jnp.concatenate([v, zeros], axis=2)
        pos = jnp.concatenate([pos, jnp.full((pad,), -1, jnp.int32)])
    return HybridCache(k=k, v=v, slot_pos=pos, conv=conv,
                       h=h.astype(jnp.float32),
                       length=jnp.asarray(n_tokens, jnp.int32))


def compose_encdec_cache(cfg, cross_artifacts: Sequence[Tuple], dec_buf: int,
                         dtype=None) -> EncDecCache:
    """Whisper: concatenate materialized cross-KVs of the retrieved audio chunks
    along the encoder axis; decoder self-cache starts empty."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    ck = jnp.concatenate([a[0] for a in cross_artifacts], axis=2).astype(dtype)
    cv = jnp.concatenate([a[1] for a in cross_artifacts], axis=2).astype(dtype)
    n_layers, batch = ck.shape[0], ck.shape[1]
    shape = (n_layers, batch, dec_buf, cfg.num_kv_heads, cfg.head_dim)
    return EncDecCache(
        cross_k=ck, cross_v=cv,
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        slot_pos=jnp.full((dec_buf,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32))
