"""Selective materialization + eviction for the MatKV store (paper §III-E).

The paper's evaluation uses the deliberately-simplified *Eager,
Materialize-All* strategy; its Discussion section sketches what a deployment
needs instead. This module implements that sketch as a first-class layer:

- **Admission** (`TenDayAdmission`): materialize a chunk's KV only once its
  *observed* inter-access interval beats the Eq.-1 break-even interval —
  the ten-day rule applied per object instead of fleet-wide. First access is
  always a miss (the paper's cold start); the second access inside the
  break-even window triggers materialization (lazy, §III-B footnote).
- **Eviction** (`LruPolicy`, `LfuPolicy`, `CostAwarePolicy`): when the flash
  budget saturates, drop the KV whose loss costs least. CostAware ranks by
  (access rate x recompute cost saved per access) / bytes — i.e. evict the
  lowest $-value per byte, the direct TCO objective from §III-E.
- **`TieredStore`**: wraps any KV store with admission + eviction + stats;
  misses fall back to recompute (the caller's materializer), exactly the
  cold-start path.

Pure host-side control plane: no jax, deterministic, unit-testable.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Optional

from repro.core.economics import (GpuSpec, H100, SAMSUNG_9100_PRO, SsdSpec,
                                  break_even_interval_s)


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------

class AlwaysAdmit:
    """The paper's Eager Materialize-All baseline."""

    def on_access(self, chunk_id: str, now: Optional[float] = None) -> bool:
        return True


class TenDayAdmission:
    """Materialize once the observed inter-access interval is inside the
    per-object break-even interval T (Eq. 1). One re-access within T is the
    cheapest sufficient evidence the object is 'hot enough to store'.

    ``now_fn`` is the injectable clock used when ``on_access`` is called
    without an explicit timestamp (standalone use); ``TieredStore`` threads
    its own clock through as the explicit ``now`` so the whole admission +
    eviction stack runs on one deterministic time source in tests.
    """

    def __init__(self, gpu: GpuSpec = H100, ssd: SsdSpec = SAMSUNG_9100_PRO,
                 kv_bytes_per_token: int = 250_000,
                 now_fn: Callable[[], float] = time.monotonic):
        self.break_even_s = break_even_interval_s(gpu, ssd,
                                                  kv_bytes_per_token)
        self.now_fn = now_fn
        self._last_seen: Dict[str, float] = {}

    @classmethod
    def for_config(cls, cfg, codec=None, gpu: GpuSpec = H100,
                   ssd: SsdSpec = SAMSUNG_9100_PRO,
                   now_fn: Callable[[], float] = time.monotonic
                   ) -> "TenDayAdmission":
        """Admission priced at the *encoded* artifact size (DESIGN.md §11):
        Eq. 1 trades storage cost against recompute cost per byte actually
        written, so an int8 codec (~0.52x the bytes) stretches the
        break-even interval — more chunks clear the bar."""
        from repro.core.quantize import get_codec
        per_token = get_codec(codec).kv_bytes_per_token(cfg)
        if per_token <= 0:
            raise ValueError(
                f"{cfg.name} ({cfg.family}): no per-token KV to price — "
                f"Eq. 1 admission applies to attention-KV families only")
        return cls(gpu, ssd, kv_bytes_per_token=per_token, now_fn=now_fn)

    def on_access(self, chunk_id: str, now: Optional[float] = None) -> bool:
        if now is None:
            now = self.now_fn()
        prev = self._last_seen.get(chunk_id)
        self._last_seen[chunk_id] = now
        return prev is not None and (now - prev) <= self.break_even_s


# ---------------------------------------------------------------------------
# eviction
# ---------------------------------------------------------------------------

@dataclass
class _Entry:
    nbytes: int
    hits: int = 0
    last_access: float = 0.0
    first_access: float = 0.0


class LruPolicy:
    def victim(self, entries: "OrderedDict[str, _Entry]") -> str:
        return min(entries, key=lambda c: entries[c].last_access)


class LfuPolicy:
    def victim(self, entries: "OrderedDict[str, _Entry]") -> str:
        return min(entries, key=lambda c: (entries[c].hits,
                                           entries[c].last_access))


class CostAwarePolicy:
    """Evict the lowest saved-$-per-byte object: value = hit rate x
    (recompute cost per access) / size. Ties the eviction order directly to
    the paper's TCO argument."""

    def __init__(self, now_fn: Callable[[], float] = time.monotonic):
        self._now = now_fn

    def victim(self, entries: "OrderedDict[str, _Entry]") -> str:
        now = self._now()

        def value(c: str) -> float:
            e = entries[c]
            age = max(now - e.first_access, 1e-9)
            rate = e.hits / age
            return rate / max(e.nbytes, 1)

        return min(entries, key=value)


# ---------------------------------------------------------------------------
# the tiered store
# ---------------------------------------------------------------------------

@dataclass
class TierStats:
    hits: int = 0
    misses: int = 0
    admissions: int = 0
    rejections: int = 0
    evictions: int = 0
    bytes_evicted: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TieredStore:
    """Admission-gated, capacity-bounded wrapper around a flash KV store.

    ``get(chunk_id)`` returns the payload on hit or None on miss (caller
    recomputes — the cold-start path). ``offer(chunk_id, payload)`` runs the
    admission policy and, if admitted, writes through to the backing store,
    evicting victims while over budget.
    """

    def __init__(self, store, capacity_bytes: int,
                 admission=None, eviction=None,
                 now_fn: Callable[[], float] = time.monotonic):
        self.store = store
        self.capacity_bytes = capacity_bytes
        self.admission = admission or AlwaysAdmit()
        self.eviction = eviction or LruPolicy()
        self.stats = TierStats()
        self._now = now_fn
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self._used = 0

    # -- read path -------------------------------------------------------------
    def get(self, chunk_id: str) -> Optional[bytes]:
        now = self._now()
        entry = self._entries.get(chunk_id)
        if entry is None:
            self.stats.misses += 1
            # a miss is still an access: the caller's recompute -> offer path
            # feeds the admission estimator via on_access inside offer()
            return None
        entry.hits += 1
        entry.last_access = now
        # a hit is an access too. Without feeding the admission clock here,
        # _last_seen goes stale while the chunk is resident, so a hot chunk
        # that later gets evicted is wrongly rejected at its next offer (the
        # interval is measured from the long-ago admission instead of the
        # last access) — the admit decision is irrelevant on a hit, only the
        # clock update matters.
        self.admission.on_access(chunk_id, now)
        self.stats.hits += 1
        return self.store.get(chunk_id)

    # -- write path ------------------------------------------------------------
    def offer(self, chunk_id: str, payload: bytes) -> bool:
        """Admission-gated materialization; returns True if stored."""
        now = self._now()
        if chunk_id in self._entries:
            return True
        if not self.admission.on_access(chunk_id, now):
            self.stats.rejections += 1
            return False
        if len(payload) > self.capacity_bytes:
            self.stats.rejections += 1
            return False
        while self._used + len(payload) > self.capacity_bytes:
            self._evict_one()
        self.store.put(chunk_id, payload)
        self._entries[chunk_id] = _Entry(nbytes=len(payload),
                                         last_access=now, first_access=now)
        self._used += len(payload)
        self.stats.admissions += 1
        return True

    def delete(self, chunk_id: str) -> None:
        e = self._entries.pop(chunk_id, None)
        if e is not None:
            self._used -= e.nbytes
            self.store.delete(chunk_id)

    def _evict_one(self) -> None:
        victim = self.eviction.victim(self._entries)
        e = self._entries.pop(victim)
        self._used -= e.nbytes
        self.store.delete(victim)
        self.stats.evictions += 1
        self.stats.bytes_evicted += e.nbytes

    # -- introspection -----------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return self._used

    def __contains__(self, chunk_id: str) -> bool:
        return chunk_id in self._entries
