"""CacheBlend baseline (Yao et al., EuroSys'25) — the paper's closest comparison.

CacheBlend also loads independently-prefilled per-chunk KVs, but then *selectively
recomputes* a fraction r (paper uses 18%) of token positions with full
cross-chunk attention, "blending" the result into the cache. Selection uses the
HKVD heuristic: tokens whose layer-0 true KV deviates most from the cached KV.

Implemented for attention-KV families (dense / vlm / moe — CacheBlend is an
attention-level technique). The selective re-prefill runs the chosen tokens
through every layer, attending to the full composed cache, and scatters their
corrected K/V back into the cache — so later layers and the final decode see the
blended values. Cost ~= r * vanilla prefill, matching the paper's speed story.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention, project_kv, project_q
from repro.models.cache import AttnCache
from repro.models.mlp import mlp
from repro.models.moe import moe_ffn
from repro.models.norms import rms_norm
from repro.models.rope import rope_q_k
from repro.models.scan_utils import scan_layers
from repro.models.transformer import embed_inputs


def hkvd_select(cfg, params, tokens, cache: AttnCache, ratio: float):
    """Pick the ceil(ratio * S) token positions whose layer-0 K most deviates
    from the cached K (CacheBlend's HKVD heuristic). Returns sorted (n_sel,)."""
    x = embed_inputs(cfg, params, tokens)
    s = x.shape[1]
    layer0 = jax.tree.map(lambda a: a[0], params.get("layers"))
    if cfg.family == "moe" and params.get("prefix_layers"):
        layer0 = params["prefix_layers"][0]
    h = rms_norm(x, layer0["ln1"], cfg.norm_eps)
    k_true, _ = project_kv(cfg, layer0["attn"], h)
    if cfg.use_rope:
        pos = jnp.arange(s, dtype=jnp.int32)
        _, k_true = rope_q_k(k_true, k_true, pos, cfg.rope_theta)
    k_cached = cache.k[0, :, :s]                     # (B, S, KV, hd)
    dev = jnp.sum((k_true.astype(jnp.float32)
                   - k_cached.astype(jnp.float32)) ** 2, axis=(0, 2, 3))
    n_sel = max(1, math.ceil(ratio * s))
    _, idx = jax.lax.top_k(dev, n_sel)
    return jnp.sort(idx)


def blend(cfg, params, tokens, cache: AttnCache, ratio: float = 0.18,
          sel=None) -> Tuple[AttnCache, jnp.ndarray]:
    """Selective recompute: returns (blended cache, selected positions)."""
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError("CacheBlend applies to attention-KV families only")
    if sel is None:
        sel = hkvd_select(cfg, params, tokens, cache, ratio)
    sel = sel.astype(jnp.int32)
    x_all = embed_inputs(cfg, params, tokens)
    x = jnp.take(x_all, sel, axis=1)                 # (B, n_sel, D)
    s_total = tokens.shape[1]
    k_pos = jnp.arange(cache.buf_size, dtype=jnp.int32)
    k_pos = jnp.where(k_pos < s_total, k_pos, -1)

    def layer_pass(x, lp, ck, cv):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = project_q(cfg, lp["attn"], h)
        k_new, v_new = project_kv(cfg, lp["attn"], h)
        if cfg.use_rope:
            q, k_new = rope_q_k(q, k_new, sel, cfg.rope_theta)
        # blend this layer's cache BEFORE attending (selected see each other)
        ck = ck.at[:, sel].set(k_new.astype(ck.dtype))
        cv = cv.at[:, sel].set(v_new.astype(cv.dtype))
        a = flash_attention(q, ck, cv, sel, k_pos, cfg.sliding_window, True)
        x = x + a.reshape(x.shape[0], x.shape[1], cfg.q_dim) @ lp["attn"]["wo"]
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            out, _ = moe_ffn(cfg, lp["moe"], h2)
        else:
            out = mlp(cfg, lp["mlp"], h2)
        return x + out, ck, cv

    new_k, new_v = cache.k, cache.v
    offset = 0
    if cfg.family == "moe" and params.get("prefix_layers"):
        for i, lp in enumerate(params["prefix_layers"]):
            x, ck, cv = layer_pass(x, lp, new_k[i], new_v[i])
            new_k = new_k.at[i].set(ck)
            new_v = new_v.at[i].set(cv)
        offset = len(params["prefix_layers"])

    def scan_body(x, xs):
        lp, ck, cv = xs
        x, ck, cv = layer_pass(x, lp, ck, cv)
        return x, (ck, cv)

    x, (ks, vs) = scan_layers(scan_body, x,
                               (params["layers"], new_k[offset:], new_v[offset:]))
    new_k = new_k.at[offset:].set(ks) if offset else ks
    new_v = new_v.at[offset:].set(vs) if offset else vs
    return AttnCache(k=new_k, v=new_v, slot_pos=cache.slot_pos,
                     length=cache.length), sel
