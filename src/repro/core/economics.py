"""The ten-day rule (paper §II-C, Eq. 1) and the MatKV cost/energy model.

Gray's five-minute-rule break-even logic, adapted: keeping a chunk's KV on
flash beats GPU recomputation when the chunk is re-retrieved at least once per
break-even interval T.

Unit analysis (we reproduce the paper's ~10-day headline): amortized cost of
regenerating 1 MB of KV on the GPU per access = $GPU / (KV_MB_per_s * lifetime)
vs. cost of holding 1 MB on flash for interval T = $per_MB * (T / lifetime).
Break-even:  T = $GPU / (KV_MB_per_s * $per_MB).
With H100 ($50,000, 500 MB KV/s for LLaMA-70B) and 9100 Pro ($0.0001/MB):
T = 50_000 / (500 * 1e-4) = 1e6 s ≈ 11.6 days — the paper's "ten-day rule".
(The paper's Eq. 1 prints an extra Sec/MB term; its own worked number matches
the form above, which we therefore implement.)
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GpuSpec:
    name: str
    price_usd: float
    peak_power_w: float
    # prefill throughput for the reference model, tokens/s (paper: LLaMA-70B
    # 1,024 tokens in ~500 ms on H100)
    prefill_tokens_per_s: float
    decode_tokens_per_s: float


@dataclass(frozen=True)
class SsdSpec:
    name: str
    price_usd_per_gb: float
    read_gbps: float       # GB/s sequential read
    active_power_w: float


# Paper §II-C / §V-A hardware constants.
H100 = GpuSpec("H100", 50_000.0, 350.0, prefill_tokens_per_s=2048.0,
               decode_tokens_per_s=30.0)
RTX4090 = GpuSpec("RTX4090", 1_600.0, 450.0, prefill_tokens_per_s=2048.0 / 6,
                  decode_tokens_per_s=22.0)
SAMSUNG_9100_PRO = SsdSpec("Samsung 9100 Pro", 0.1, 14.7, 7.0)
RAID0_9100_PRO_X4 = SsdSpec("4x 9100 Pro RAID-0", 0.1, 58.8, 28.0)
PM9A3 = SsdSpec("Samsung PM9A3", 0.12, 6.5, 8.0)
DRAM_TIER = SsdSpec("DRAM tier", 2.5, 400.0, 90.0)

SECONDS_PER_DAY = 86_400.0


def kv_mb_per_gpu_second(kv_bytes_per_token: int, prefill_tokens_per_s: float
                         ) -> float:
    return kv_bytes_per_token * prefill_tokens_per_s / 1e6


def break_even_interval_s(gpu: GpuSpec, ssd: SsdSpec,
                          kv_bytes_per_token: int) -> float:
    """Eq. 1: max re-access interval for which flash materialization wins."""
    kv_rate = kv_mb_per_gpu_second(kv_bytes_per_token, gpu.prefill_tokens_per_s)
    usd_per_mb = ssd.price_usd_per_gb / 1024.0
    return gpu.price_usd / (kv_rate * usd_per_mb)


def break_even_interval_days(gpu: GpuSpec, ssd: SsdSpec,
                             kv_bytes_per_token: int) -> float:
    return break_even_interval_s(gpu, ssd, kv_bytes_per_token) / SECONDS_PER_DAY


def prefill_cost(gpu: GpuSpec, n_tokens: int):
    """(seconds, joules) to recompute a chunk's KV on the GPU."""
    t = n_tokens / gpu.prefill_tokens_per_s
    return t, t * gpu.peak_power_w


def load_cost(ssd: SsdSpec, kv_bytes: int):
    """(seconds, joules) to read materialized KV from storage."""
    t = kv_bytes / (ssd.read_gbps * 1e9)
    return t, t * ssd.active_power_w


def cost_ratio_per_access(gpu: GpuSpec, ssd: SsdSpec, kv_bytes_per_token: int,
                          n_tokens: int, access_interval_s: float) -> float:
    """$ cost of GPU recompute / $ cost of SSD storage, per access. > 1 means
    MatKV wins. Paper: ~100x at one access/hour for a 1,024-token chunk."""
    gpu_lifetime_s = 3.0 * 365 * SECONDS_PER_DAY  # 3-year amortization
    t_prefill, _ = prefill_cost(gpu, n_tokens)
    gpu_cost = gpu.price_usd * t_prefill / gpu_lifetime_s
    kv_mb = kv_bytes_per_token * n_tokens / 1e6
    ssd_cost = (ssd.price_usd_per_gb / 1024.0) * kv_mb \
        * (access_interval_s / gpu_lifetime_s)
    return gpu_cost / ssd_cost
